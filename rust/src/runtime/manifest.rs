//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime.  One manifest per SOI variant describes the model config,
//! the partial-state inventory, the parameter layout of `weights.bin`, and
//! the phase → executable map.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

/// Numeric execution precision of a variant artifact (DESIGN.md §10).
///
/// `F32` is the classic native/pjrt float path.  `Int8` selects the
/// quantized executable ([`crate::quant::QuantVariant`]): int8 weights
/// with per-channel (input-channel-refined) scales, s16 activations, and
/// i32 accumulators.  An `Int8` manifest must carry a baked [`QuantSpec`]
/// — the activation scales calibrated at build time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    /// 32-bit float execution (the default).
    F32,
    /// Quantized execution: int8 weights, s16 activations, i32 accumulators.
    Int8,
}

impl Dtype {
    /// Parse a dtype name ("f32" | "int8").
    pub fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "int8" => Ok(Dtype::Int8),
            other => bail!("unknown dtype '{other}' (f32 | int8)"),
        }
    }

    /// Canonical name ("f32" | "int8") — the `:<dtype>` suffix of the
    /// variant-spec grammar and the `dtype` field of JSON reports.
    pub fn as_str(&self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::Int8 => "int8",
        }
    }
}

/// Baked quantization parameters of an int8 artifact (DESIGN.md §10):
/// the static activation scales `quant::calibrate` derived from
/// synthesized activations at build time.  Weight scales are *not* here —
/// they are a pure function of the weights and are re-derived when the
/// weights are prepared for execution.
///
/// Every scale maps a real value `v` to the s16 code `round(v / s)`;
/// pre-activation and post-activation ranges share one scale per layer
/// (ELU never grows a magnitude), which is what makes the positive half
/// of the ELU LUT an exact identity.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantSpec {
    /// Input-frame activation scale.
    pub s_in: f32,
    /// Per encoder layer (index `l - 1`): the shared pre/post-activation
    /// scale of `enc l`'s conv output.
    pub s_enc: Vec<f32>,
    /// Per decoder layer (index `l - 1`): the shared pre/post-activation
    /// scale of `dec l`'s conv output.
    pub s_dec: Vec<f32>,
    /// Per tconv-extrapolation position: the scale of `up p`'s output
    /// (duplication extrapolation reuses `s_dec[p - 1]` and has no entry).
    pub s_up: BTreeMap<usize, f32>,
}

impl QuantSpec {
    /// Parse the baked `quant` section (manifest.json / artifact.json —
    /// the artifact loader shares this parser).
    pub(crate) fn from_json(v: &Json) -> Result<QuantSpec> {
        let f32_arr = |j: &Json, what: &str| -> Result<Vec<f32>> {
            j.as_arr()
                .with_context(|| format!("quant.{what}: expected array"))?
                .iter()
                .map(|d| d.as_f64().map(|f| f as f32).context("quant scale"))
                .collect()
        };
        let mut s_up = BTreeMap::new();
        if let Some(kv) = v.get("s_up").and_then(|j| j.as_obj()) {
            for (k, val) in kv {
                let p: usize = k.parse().with_context(|| format!("quant.s_up key '{k}'"))?;
                s_up.insert(p, val.as_f64().context("quant.s_up value")? as f32);
            }
        }
        Ok(QuantSpec {
            s_in: v
                .req("s_in")
                .map_err(anyhow::Error::from)?
                .as_f64()
                .context("quant.s_in")? as f32,
            s_enc: f32_arr(v.req("s_enc").map_err(anyhow::Error::from)?, "s_enc")?,
            s_dec: f32_arr(v.req("s_dec").map_err(anyhow::Error::from)?, "s_dec")?,
            s_up,
        })
    }

    /// Structural validation against the owning config: one scale per
    /// layer, every scale strictly positive and finite.
    pub fn validate(&self, cfg: &ModelConfig) -> Result<()> {
        let d = cfg.depth();
        if self.s_enc.len() != d || self.s_dec.len() != d {
            bail!(
                "quant spec has {} enc / {} dec scales for depth {d}",
                self.s_enc.len(),
                self.s_dec.len()
            );
        }
        for p in self.s_up.keys() {
            if !cfg.scc.contains(p) || cfg.extrap_of(*p) != "tconv" {
                bail!("quant spec has an s_up scale at {p}, not a tconv S-CC position");
            }
        }
        for &p in &cfg.scc {
            if cfg.extrap_of(p) == "tconv" && !self.s_up.contains_key(&p) {
                bail!("quant spec lacks the s_up scale for tconv S-CC position {p}");
            }
        }
        let all = std::iter::once(self.s_in)
            .chain(self.s_enc.iter().copied())
            .chain(self.s_dec.iter().copied())
            .chain(self.s_up.values().copied());
        for s in all {
            if !(s.is_finite() && s > 0.0) {
                bail!("quant spec holds a non-positive or non-finite scale {s}");
            }
        }
        Ok(())
    }
}

/// Mirror of python's `UNetConfig` (the fields rust needs).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    /// Samples per frame (input/output feature size).
    pub feat: usize,
    /// Encoder output channels per layer, shallow to deep.
    pub channels: Vec<usize>,
    /// Temporal kernel width of every conv layer.
    pub kernel: usize,
    /// Encoder positions carrying S-CC stride compression (sorted, 1-based).
    pub scc: Vec<usize>,
    /// Encoder position of the FP shift delay line, when present.
    pub shift_pos: Option<usize>,
    /// FP delay-line length in frames (prediction length).
    pub shift: usize,
    /// Extrapolation kind per S-CC position ("duplicate" | "tconv").
    pub extrap: Vec<String>,
    /// Offline-only interpolation reconstruction (App. D), when present.
    pub interp: Option<String>,
}

impl ModelConfig {
    /// Number of encoder (== decoder) layers.
    pub fn depth(&self) -> usize {
        self.channels.len()
    }

    /// Length of the repeating SOI inference pattern.
    pub fn period(&self) -> usize {
        1 << self.scc.len()
    }

    /// Rate divisor of encoder layer `l`'s *input* domain (1-based).
    pub fn r_in(&self, l: usize) -> usize {
        1 << self.scc.iter().filter(|&&p| p < l).count()
    }

    /// Rate divisor of encoder layer `l`'s *output* domain.
    pub fn r_out(&self, l: usize) -> usize {
        1 << self.scc.iter().filter(|&&p| p <= l).count()
    }

    /// Input channels of encoder layer `l` (1-based).
    pub fn enc_in_ch(&self, l: usize) -> usize {
        if l == 1 {
            self.feat
        } else {
            self.channels[l - 2]
        }
    }

    /// Output channels of encoder layer `l`.
    pub fn enc_out_ch(&self, l: usize) -> usize {
        self.channels[l - 1]
    }

    /// Output channels of decoder layer `l`.
    pub fn dec_out_ch(&self, l: usize) -> usize {
        self.channels[l.saturating_sub(2)]
    }

    /// Input channels of decoder layer `l` (deep input + skip).
    pub fn dec_in_ch(&self, l: usize) -> usize {
        let d = self.depth();
        if l == d {
            self.channels[d - 1]
        } else {
            self.dec_out_ch(l + 1) + self.channels[l - 1]
        }
    }

    /// Extrapolation kind at S-CC position `p` ("duplicate" | "tconv").
    pub fn extrap_of(&self, p: usize) -> &str {
        self.scc
            .iter()
            .position(|&q| q == p)
            .and_then(|i| self.extrap.get(i))
            .map(|s| s.as_str())
            .unwrap_or("duplicate")
    }
}

/// One named tensor slot (state or parameter).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    /// Slot name ("enc3.w", "shift.fifo", ...).
    pub name: String,
    /// Tensor shape, outermost first.
    pub shape: Vec<usize>,
}

impl TensorSpec {
    /// Total element count of the slot.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Per-layer MAC entry (cross-checked against `complexity::unet`).
#[derive(Debug, Clone, PartialEq)]
pub struct LayerMacs {
    /// Layer label matching the complexity engine's naming.
    pub name: String,
    /// MACs per output frame in the layer's own rate domain.
    pub macs: u64,
    /// The layer computes every `rate_div` input frames.
    pub rate_div: u64,
}

/// Parsed `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Variant name (artifact directory name).
    pub name: String,
    /// Model topology the artifact was built from.
    pub config: ModelConfig,
    /// Numeric execution precision ([`Dtype::F32`] unless the artifact
    /// was built for quantized execution).
    pub dtype: Dtype,
    /// Baked quantization parameters — required when `dtype` is
    /// [`Dtype::Int8`], absent otherwise.
    pub quant: Option<QuantSpec>,
    /// Length of the repeating inference pattern (2^|scc|).
    pub period: usize,
    /// Whether the variant can run online (interp variants cannot).
    pub streamable: bool,
    /// Sequence length the offline executable was lowered for.
    pub offline_t: usize,
    /// Total f32 length of the packed state vector the step executables
    /// exchange (all per-layer states concatenated in spec order); 0 for
    /// legacy per-state artifacts.
    pub packed_states: usize,
    /// Per-stream partial-state inventory, in canonical order.
    pub states: Vec<TensorSpec>,
    /// Parameter inventory, in `weights.bin` order.
    pub params: Vec<TensorSpec>,
    /// key (e.g. "step_p0", "pre_p1", "offline") → hlo file name.
    pub executables: BTreeMap<String, String>,
    /// Per-layer MAC table (cross-checked against `complexity::unet`).
    pub layer_macs: Vec<LayerMacs>,
    /// Average MACs per frame under the SOI schedule.
    pub macs_per_frame: f64,
    /// Fraction of full-rate work in the FP-delayed region (0 for PP).
    pub precomputed_fraction: f64,
    /// Total parameter count.
    pub param_count: usize,
    /// Bytes of per-stream partial state.
    pub state_bytes: usize,
    /// Build-time training metrics (si_snri etc.).
    pub train_metrics: BTreeMap<String, f64>,
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
}

fn specs_from(v: &Json, what: &str) -> Result<Vec<TensorSpec>> {
    let arr = v
        .as_arr()
        .with_context(|| format!("{what}: expected array"))?;
    let mut out = Vec::with_capacity(arr.len());
    for e in arr {
        let name = e
            .req("name")
            .map_err(anyhow::Error::from)?
            .as_str()
            .context("name must be a string")?
            .to_string();
        let shape = e
            .req("shape")
            .map_err(anyhow::Error::from)?
            .as_arr()
            .context("shape must be an array")?
            .iter()
            .map(|d| d.as_usize().context("shape dim"))
            .collect::<Result<Vec<_>>>()?;
        out.push(TensorSpec { name, shape });
    }
    Ok(out)
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        Self::from_json(&v, dir)
    }

    /// Parse a manifest from its JSON document; `dir` becomes
    /// [`Manifest::dir`] for resolving executable paths.
    pub fn from_json(v: &Json, dir: &Path) -> Result<Manifest> {
        let cfg = v.req("config").map_err(anyhow::Error::from)?;
        let usize_arr = |j: &Json| -> Result<Vec<usize>> {
            j.as_arr()
                .context("expected array")?
                .iter()
                .map(|d| d.as_usize().context("expected usize"))
                .collect()
        };
        let config = ModelConfig {
            feat: cfg.req("feat").map_err(anyhow::Error::from)?.as_usize().context("feat")?,
            channels: usize_arr(cfg.req("channels").map_err(anyhow::Error::from)?)?,
            kernel: cfg.req("kernel").map_err(anyhow::Error::from)?.as_usize().context("kernel")?,
            scc: usize_arr(cfg.req("scc").map_err(anyhow::Error::from)?)?,
            shift_pos: cfg.get("shift_pos").and_then(|j| j.as_usize()),
            shift: cfg.get("shift").and_then(|j| j.as_usize()).unwrap_or(1),
            extrap: cfg
                .req("extrap")
                .map_err(anyhow::Error::from)?
                .as_arr()
                .context("extrap")?
                .iter()
                .map(|e| e.as_str().unwrap_or("duplicate").to_string())
                .collect(),
            interp: cfg
                .get("interp")
                .and_then(|j| j.as_str())
                .map(|s| s.to_string()),
        };

        let mut executables = BTreeMap::new();
        if let Some(kv) = v.req("executables").map_err(anyhow::Error::from)?.as_obj() {
            for (k, val) in kv {
                executables.insert(
                    k.clone(),
                    val.as_str().context("executable file name")?.to_string(),
                );
            }
        }

        let mut layer_macs = Vec::new();
        for e in v
            .req("layer_macs")
            .map_err(anyhow::Error::from)?
            .as_arr()
            .context("layer_macs")?
        {
            layer_macs.push(LayerMacs {
                name: e
                    .req("name")
                    .map_err(anyhow::Error::from)?
                    .as_str()
                    .context("layer name")?
                    .to_string(),
                macs: e.req("macs").map_err(anyhow::Error::from)?.as_i64().context("macs")? as u64,
                rate_div: e
                    .req("rate_div")
                    .map_err(anyhow::Error::from)?
                    .as_i64()
                    .context("rate_div")? as u64,
            });
        }

        let mut train_metrics = BTreeMap::new();
        if let Some(m) = v.get("train_metrics").and_then(|m| m.as_obj()) {
            for (k, val) in m {
                if let Some(f) = val.as_f64() {
                    train_metrics.insert(k.clone(), f);
                }
            }
        }

        let m = Manifest {
            name: v
                .req("name")
                .map_err(anyhow::Error::from)?
                .as_str()
                .context("name")?
                .to_string(),
            config,
            dtype: match v.get("dtype").and_then(|j| j.as_str()) {
                Some(s) => Dtype::parse(s)?,
                None => Dtype::F32,
            },
            quant: match v.get("quant") {
                Some(q) if !q.is_null() => Some(QuantSpec::from_json(q)?),
                _ => None,
            },
            period: v.req("period").map_err(anyhow::Error::from)?.as_usize().context("period")?,
            streamable: v
                .get("streamable")
                .and_then(|j| j.as_bool())
                .unwrap_or(true),
            offline_t: v
                .get("offline_t")
                .and_then(|j| j.as_usize())
                .unwrap_or(256),
            packed_states: v
                .get("packed_states")
                .and_then(|j| j.as_usize())
                .unwrap_or(0),
            states: specs_from(v.req("states").map_err(anyhow::Error::from)?, "states")?,
            params: specs_from(v.req("params").map_err(anyhow::Error::from)?, "params")?,
            executables,
            layer_macs,
            macs_per_frame: v
                .get("macs_per_frame")
                .and_then(|j| j.as_f64())
                .unwrap_or(0.0),
            precomputed_fraction: v
                .get("precomputed_fraction")
                .and_then(|j| j.as_f64())
                .unwrap_or(0.0),
            param_count: v.get("param_count").and_then(|j| j.as_usize()).unwrap_or(0),
            state_bytes: v.get("state_bytes").and_then(|j| j.as_usize()).unwrap_or(0),
            train_metrics,
            dir: dir.to_path_buf(),
        };
        m.validate()?;
        Ok(m)
    }

    fn validate(&self) -> Result<()> {
        if self.period == 0 || !self.period.is_power_of_two() {
            bail!("{}: period must be a power of two", self.name);
        }
        if self.dtype == Dtype::Int8 {
            let Some(q) = &self.quant else {
                bail!(
                    "{}: dtype int8 requires baked quant params (the 'quant' \
                     section calibrated at build time)",
                    self.name
                );
            };
            q.validate(&self.config)
                .with_context(|| format!("{}: invalid quant spec", self.name))?;
        }
        // Native-interpreted artifacts ship no HLO at all (empty
        // executables map); when executables are present the phase map
        // must be complete.
        if !self.executables.is_empty() {
            if self.streamable {
                for phase in 0..self.period {
                    let key = format!("step_p{phase}");
                    if !self.executables.contains_key(&key) {
                        bail!("{}: missing executable {key}", self.name);
                    }
                }
            }
            if !self.executables.contains_key("offline") {
                bail!("{}: missing offline executable", self.name);
            }
        }
        Ok(())
    }

    /// Does this variant carry an FP precompute split?  True when the
    /// config places an FP shift (native backend) or when the artifact
    /// ships `pre_*` executables (pjrt backend).
    pub fn has_fp_split(&self) -> bool {
        self.config.shift_pos.is_some() || self.executables.contains_key("pre_p0")
    }

    /// Path of an executable by key ("step_p0", "offline", ...).
    pub fn exe_path(&self, key: &str) -> Result<PathBuf> {
        let f = self
            .executables
            .get(key)
            .with_context(|| format!("{}: no executable '{key}'", self.name))?;
        Ok(self.dir.join(f))
    }

    /// Training SI-SNRi (dB) recorded at build time.
    pub fn si_snri(&self) -> Option<f64> {
        self.train_metrics.get("si_snri").copied()
    }

    /// Average MACs/frame relative to a baseline manifest, in percent.
    pub fn complexity_retain_vs(&self, baseline: &Manifest) -> f64 {
        100.0 * self.macs_per_frame / baseline.macs_per_frame
    }
}

/// List variant directories under an artifacts root (sorted by name).
pub fn list_variants(root: &Path) -> Result<Vec<String>> {
    let mut names = Vec::new();
    for entry in fs::read_dir(root).with_context(|| format!("reading {}", root.display()))? {
        let e = entry?;
        if e.path().join("manifest.json").exists() {
            names.push(e.file_name().to_string_lossy().to_string());
        }
    }
    names.sort();
    Ok(names)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini_manifest_json() -> String {
        r#"{
          "name": "t",
          "config": {"feat": 4, "channels": [4, 6], "kernel": 3, "scc": [1],
                     "shift_pos": null, "shift": 1, "extrap": ["duplicate"],
                     "interp": null},
          "period": 2,
          "streamable": true,
          "offline_t": 16,
          "states": [{"name": "enc1.win", "shape": [4, 2]}],
          "params": [{"name": "enc1.w", "shape": [6, 4, 3]}],
          "executables": {"step_p0": "a.hlo.txt", "step_p1": "b.hlo.txt",
                           "offline": "o.hlo.txt"},
          "layer_macs": [{"name": "enc1", "macs": 72, "rate_div": 2}],
          "macs_per_frame": 36.0,
          "precomputed_fraction": 0.0,
          "param_count": 72,
          "state_bytes": 32,
          "train_metrics": {"si_snri": 1.5}
        }"#
        .to_string()
    }

    #[test]
    fn parses_manifest() {
        let v = json::parse(&mini_manifest_json()).unwrap();
        let m = Manifest::from_json(&v, Path::new("/tmp/x")).unwrap();
        assert_eq!(m.name, "t");
        assert_eq!(m.config.channels, vec![4, 6]);
        assert_eq!(m.period, 2);
        assert_eq!(m.states[0].elements(), 8);
        assert_eq!(m.si_snri(), Some(1.5));
        assert!(!m.has_fp_split());
        assert_eq!(m.exe_path("offline").unwrap(), PathBuf::from("/tmp/x/o.hlo.txt"));
    }

    #[test]
    fn rejects_missing_phase() {
        let bad = mini_manifest_json().replace(r#""step_p1": "b.hlo.txt","#, "");
        let v = json::parse(&bad).unwrap();
        assert!(Manifest::from_json(&v, Path::new("/tmp")).is_err());
    }

    #[test]
    fn parses_dtype_and_quant() {
        // default: f32, no quant section
        let v = json::parse(&mini_manifest_json()).unwrap();
        let m = Manifest::from_json(&v, Path::new("/tmp/x")).unwrap();
        assert_eq!(m.dtype, Dtype::F32);
        assert!(m.quant.is_none());

        // int8 with a baked quant spec round-trips
        let with_quant = mini_manifest_json().replace(
            r#""period": 2,"#,
            r#""period": 2,
               "dtype": "int8",
               "quant": {"s_in": 0.001, "s_enc": [0.002, 0.003],
                          "s_dec": [0.004, 0.005], "s_up": {}},"#,
        );
        let v = json::parse(&with_quant).unwrap();
        let m = Manifest::from_json(&v, Path::new("/tmp/x")).unwrap();
        assert_eq!(m.dtype, Dtype::Int8);
        let q = m.quant.unwrap();
        assert_eq!(q.s_enc, vec![0.002, 0.003]);
        assert!((q.s_in - 0.001).abs() < 1e-9);

        // int8 without quant params is rejected
        let bad = mini_manifest_json()
            .replace(r#""period": 2,"#, r#""period": 2, "dtype": "int8","#);
        let v = json::parse(&bad).unwrap();
        assert!(Manifest::from_json(&v, Path::new("/tmp/x")).is_err());
    }

    #[test]
    fn quant_spec_validation_checks_shapes_and_positivity() {
        let cfg = ModelConfig {
            feat: 4,
            channels: vec![4, 6],
            kernel: 3,
            scc: vec![1],
            shift_pos: None,
            shift: 1,
            extrap: vec!["tconv".into()],
            interp: None,
        };
        let mut q = QuantSpec {
            s_in: 0.1,
            s_enc: vec![0.1, 0.1],
            s_dec: vec![0.1, 0.1],
            s_up: BTreeMap::from([(1usize, 0.1f32)]),
        };
        q.validate(&cfg).unwrap();
        q.s_up.clear();
        assert!(q.validate(&cfg).is_err(), "tconv position needs s_up");
        q.s_up.insert(1, 0.1);
        q.s_enc.pop();
        assert!(q.validate(&cfg).is_err(), "one scale per layer");
        q.s_enc.push(0.0);
        assert!(q.validate(&cfg).is_err(), "scales must be positive");
        assert_eq!(Dtype::parse("int8").unwrap(), Dtype::Int8);
        assert_eq!(Dtype::Int8.as_str(), "int8");
        assert!(Dtype::parse("fp16").is_err());
    }

    #[test]
    fn rejects_bad_period() {
        let bad = mini_manifest_json().replace(r#""period": 2"#, r#""period": 3"#);
        let v = json::parse(&bad).unwrap();
        assert!(Manifest::from_json(&v, Path::new("/tmp")).is_err());
    }
}
