//! PJRT runtime (L3 ⇄ artifacts bridge): loads HLO-text artifacts emitted
//! by `python/compile/aot.py`, compiles them on the PJRT CPU client, and
//! executes them from the coordinator hot path.  Python never runs here.

pub mod engine;
pub mod manifest;

pub use engine::{CompiledVariant, DeviceWeights, Executable, Runtime, StateSet, Weights};
pub use manifest::{list_variants, LayerMacs, Manifest, ModelConfig, TensorSpec};
