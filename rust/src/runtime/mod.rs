//! Runtime (L3 ⇄ artifacts bridge): loads variant manifests (and, for
//! trained artifacts, `weights.bin`) and executes them through a
//! pluggable [`crate::backend::InferenceBackend`] — the pure-Rust native
//! interpreter by default, PJRT behind `--features pjrt`.  Python never
//! runs here.

pub mod artifact;
pub mod engine;
pub mod ladder;
pub mod manifest;
pub mod synth;

pub use crate::backend::DeviceWeights;
pub use artifact::{list_generations, Artifact, ArtifactError, ARTIFACT_SCHEMA};
pub use engine::{CompiledVariant, Runtime, StateSet, Weights};
pub use ladder::{warmup_frames, VariantLadder};
pub use manifest::{list_variants, Dtype, LayerMacs, Manifest, ModelConfig, QuantSpec, TensorSpec};
