//! Versioned, integrity-checked weight artifacts (DESIGN.md §13).
//!
//! The on-disk unit of weight shipping is a **generation directory**:
//!
//! ```text
//! gen-000042/
//!   artifact.json   — soi.artifact.v1 manifest: name, generation,
//!                     model config, dtype (+ baked quant scales),
//!                     training metrics, and a per-tensor table
//!                     {name, dtype, shape, byte_len, sha256}
//!   weights.bin     — the tensor blobs, concatenated raw little-endian
//!                     f32 in table order
//! ```
//!
//! The loader is the trust boundary between disk and the serving
//! process: it verifies the format version, the complete parameter
//! inventory (against [`synth::param_specs`] for the declared config),
//! every blob length, and every SHA-256 digest **before** constructing
//! anything — a failed load returns a typed [`ArtifactError`] and
//! leaves no partially-registered state behind (the function is pure:
//! it builds locally and returns only on full success).  The saver is
//! the mirror image and is atomic at the directory level: it stages
//! into a `*.tmp-<pid>` sibling and `rename`s into place, so a
//! generation watcher polling the root can never observe a
//! half-written generation.
//!
//! Only the *weights* travel: the runtime [`Manifest`] (state specs,
//! MAC tables, schedule metadata) is reconstructed from the embedded
//! config via [`synth::manifest`], so the artifact can never disagree
//! with the native backend about state layout or complexity accounting
//! — those are functions of the config by construction.  Weight
//! tensors are f32 regardless of execution dtype; an int8 artifact
//! additionally carries its baked activation scales and the quantized
//! backend packs codes lazily from the same f32 upload (DESIGN.md §10).

use std::collections::BTreeMap;
use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::engine::Weights;
use super::manifest::{Dtype, Manifest, ModelConfig, QuantSpec};
use super::synth;
use crate::util::json::{self, Json};
use crate::util::sha256;
use crate::util::tensor::{f32s_from_le_bytes, f32s_to_le_bytes, Tensor};

/// Format tag every artifact manifest must carry.
pub const ARTIFACT_SCHEMA: &str = "soi.artifact.v1";
/// Manifest file name inside a generation directory.
pub const MANIFEST_FILE: &str = "artifact.json";
/// Weight-blob file name inside a generation directory.
pub const WEIGHTS_FILE: &str = "weights.bin";

/// Why an artifact failed verification.  Every variant identifies one
/// concrete defect; the loader returns the first it finds and
/// constructs nothing, so a rejected generation can never be partially
/// visible to the server (the corruption matrix in
/// `rust/tests/artifact_roundtrip.rs` exercises each variant).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ArtifactError {
    /// The manifest's `schema` tag is missing or not [`ARTIFACT_SCHEMA`].
    VersionSkew {
        /// The tag found on disk (empty when absent).
        found: String,
    },
    /// A tensor required by the declared config is absent from the table.
    MissingTensor {
        /// Canonical name of the missing parameter.
        tensor: String,
    },
    /// `weights.bin` does not hold exactly the bytes the table declares
    /// (a short file, or a manifest/blob `byte_len` disagreement).
    Truncated {
        /// Total bytes the tensor table declares.
        want: u64,
        /// Bytes actually present on disk.
        got: u64,
    },
    /// A tensor's blob does not hash to its recorded digest.
    DigestMismatch {
        /// Tensor whose blob failed verification.
        tensor: String,
        /// Digest recorded in the manifest (lowercase hex).
        want: String,
        /// Digest computed from the blob (lowercase hex).
        got: String,
    },
    /// Any other structural defect: unreadable files, bad JSON, shape or
    /// dtype disagreements, duplicate or unexpected tensors, an invalid
    /// quant section.
    Malformed {
        /// Human-readable description of the defect.
        reason: String,
    },
}

impl fmt::Display for ArtifactError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ArtifactError::VersionSkew { found } => write!(
                f,
                "artifact version skew: found schema '{found}', this reader speaks '{ARTIFACT_SCHEMA}'"
            ),
            ArtifactError::MissingTensor { tensor } => {
                write!(f, "artifact is missing tensor '{tensor}'")
            }
            ArtifactError::Truncated { want, got } => write!(
                f,
                "artifact weights truncated or length-skewed: tensor table declares {want} bytes, blob holds {got}"
            ),
            ArtifactError::DigestMismatch { tensor, want, got } => write!(
                f,
                "artifact tensor '{tensor}' fails integrity check: recorded sha256 {want}, computed {got}"
            ),
            ArtifactError::Malformed { reason } => write!(f, "malformed artifact: {reason}"),
        }
    }
}

impl std::error::Error for ArtifactError {}

fn malformed<T>(reason: impl fmt::Display) -> std::result::Result<T, ArtifactError> {
    Err(ArtifactError::Malformed {
        reason: reason.to_string(),
    })
}

/// A verified weight artifact: one generation of one named variant,
/// either assembled in memory for [`Artifact::save`] or returned fully
/// verified by [`Artifact::load`].
#[derive(Debug, Clone)]
pub struct Artifact {
    /// Monotonic generation number (higher supersedes lower).
    pub generation: u64,
    /// Reconstructed runtime manifest (config, state/param specs, MAC
    /// tables; the `executables` map is empty — artifacts are
    /// native-backend weight carriers, not HLO bundles).
    pub manifest: Manifest,
    /// The verified tensors, in `manifest.params` order.
    pub weights: Weights,
}

impl Artifact {
    /// Package an in-memory variant (manifest + weights) as generation
    /// `generation`.  Fails when the weights do not match the
    /// manifest's parameter inventory — the saver refuses to write an
    /// artifact the loader would reject.
    pub fn new(manifest: Manifest, weights: Weights, generation: u64) -> Result<Artifact> {
        if weights.tensors.len() != manifest.params.len() {
            anyhow::bail!(
                "artifact '{}': {} weight tensors for {} parameter specs",
                manifest.name,
                weights.tensors.len(),
                manifest.params.len()
            );
        }
        for (t, spec) in weights.tensors.iter().zip(&manifest.params) {
            if t.shape != spec.shape {
                anyhow::bail!(
                    "artifact '{}': tensor '{}' has shape {:?}, spec wants {:?}",
                    manifest.name,
                    spec.name,
                    t.shape,
                    spec.shape
                );
            }
        }
        Ok(Artifact {
            generation,
            manifest,
            weights,
        })
    }

    /// The variant name this artifact ships weights for.
    pub fn name(&self) -> &str {
        &self.manifest.name
    }

    /// Render the deterministic `artifact.json` document (fixed key
    /// order, canonical tensor order) — byte-identical across
    /// save→load→save round trips.
    pub fn manifest_json(&self) -> String {
        let cfg = &self.manifest.config;
        let opt_num = |v: Option<usize>| match v {
            Some(n) => Json::Num(n as f64),
            None => Json::Null,
        };
        let config = Json::obj(vec![
            ("feat", Json::Num(cfg.feat as f64)),
            (
                "channels",
                Json::Arr(cfg.channels.iter().map(|&c| Json::Num(c as f64)).collect()),
            ),
            ("kernel", Json::Num(cfg.kernel as f64)),
            (
                "scc",
                Json::Arr(cfg.scc.iter().map(|&p| Json::Num(p as f64)).collect()),
            ),
            ("shift_pos", opt_num(cfg.shift_pos)),
            ("shift", Json::Num(cfg.shift as f64)),
            (
                "extrap",
                Json::Arr(cfg.extrap.iter().map(|e| Json::Str(e.clone())).collect()),
            ),
            (
                "interp",
                match &cfg.interp {
                    Some(s) => Json::Str(s.clone()),
                    None => Json::Null,
                },
            ),
        ]);
        let quant = match &self.manifest.quant {
            None => Json::Null,
            Some(q) => Json::obj(vec![
                ("s_in", Json::Num(f64::from(q.s_in))),
                (
                    "s_enc",
                    Json::Arr(q.s_enc.iter().map(|&s| Json::Num(f64::from(s))).collect()),
                ),
                (
                    "s_dec",
                    Json::Arr(q.s_dec.iter().map(|&s| Json::Num(f64::from(s))).collect()),
                ),
                (
                    "s_up",
                    Json::Obj(
                        q.s_up
                            .iter()
                            .map(|(p, &s)| (p.to_string(), Json::Num(f64::from(s))))
                            .collect(),
                    ),
                ),
            ]),
        };
        let metrics = Json::Obj(
            self.manifest
                .train_metrics
                .iter()
                .map(|(k, &v)| (k.clone(), Json::Num(v)))
                .collect(),
        );
        let tensors = Json::Arr(
            self.manifest
                .params
                .iter()
                .zip(&self.weights.tensors)
                .map(|(spec, t)| {
                    Json::obj(vec![
                        ("name", Json::Str(spec.name.clone())),
                        ("dtype", Json::Str("f32".to_string())),
                        (
                            "shape",
                            Json::Arr(t.shape.iter().map(|&d| Json::Num(d as f64)).collect()),
                        ),
                        ("byte_len", Json::Num(t.bytes() as f64)),
                        (
                            "sha256",
                            Json::Str(sha256::hex_digest(&f32s_to_le_bytes(&t.data))),
                        ),
                    ])
                })
                .collect(),
        );
        Json::obj(vec![
            ("schema", Json::Str(ARTIFACT_SCHEMA.to_string())),
            ("name", Json::Str(self.manifest.name.clone())),
            ("generation", Json::Num(self.generation as f64)),
            ("config", config),
            ("dtype", Json::Str(self.manifest.dtype.as_str().to_string())),
            ("quant", quant),
            ("train_metrics", metrics),
            ("tensors", tensors),
        ])
        .to_string_pretty()
    }

    /// Write the artifact as generation directory `dir`, atomically:
    /// both files are staged into a `*.tmp-<pid>` sibling which is
    /// `rename`d into place (replacing an existing `dir`), so a
    /// concurrent [`list_generations`] poll sees either the whole
    /// generation or none of it.
    pub fn save(&self, dir: &Path) -> Result<()> {
        let name = dir
            .file_name()
            .with_context(|| format!("artifact dir '{}' has no name", dir.display()))?
            .to_string_lossy()
            .to_string();
        let parent = dir.parent().unwrap_or_else(|| Path::new(""));
        if !parent.as_os_str().is_empty() {
            fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
        let tmp = parent.join(format!("{}.tmp-{}", name, std::process::id()));
        if tmp.exists() {
            fs::remove_dir_all(&tmp).with_context(|| format!("clearing {}", tmp.display()))?;
        }
        fs::create_dir_all(&tmp).with_context(|| format!("creating {}", tmp.display()))?;
        let mut blob = Vec::with_capacity(self.manifest.param_count * 4);
        for t in &self.weights.tensors {
            blob.extend_from_slice(&f32s_to_le_bytes(&t.data));
        }
        fs::write(tmp.join(WEIGHTS_FILE), &blob)
            .with_context(|| format!("writing {}", tmp.join(WEIGHTS_FILE).display()))?;
        fs::write(tmp.join(MANIFEST_FILE), self.manifest_json())
            .with_context(|| format!("writing {}", tmp.join(MANIFEST_FILE).display()))?;
        if dir.exists() {
            fs::remove_dir_all(dir).with_context(|| format!("replacing {}", dir.display()))?;
        }
        fs::rename(&tmp, dir)
            .with_context(|| format!("renaming {} into place", tmp.display()))?;
        Ok(())
    }

    /// Load and fully verify the generation directory `dir`.
    ///
    /// Verification order: format version ([`ArtifactError::VersionSkew`])
    /// → structure → parameter inventory vs the declared config
    /// ([`ArtifactError::MissingTensor`] / `Malformed`) → blob length
    /// ([`ArtifactError::Truncated`]) → per-tensor SHA-256
    /// ([`ArtifactError::DigestMismatch`]).  Nothing is constructed until
    /// every check passes, and manifests listing tensors in any
    /// permutation load equivalently — weights are reassembled in
    /// canonical parameter order regardless of table order.
    pub fn load(dir: &Path) -> std::result::Result<Artifact, ArtifactError> {
        let man_path = dir.join(MANIFEST_FILE);
        let text = match fs::read_to_string(&man_path) {
            Ok(t) => t,
            Err(e) => return malformed(format!("reading {}: {e}", man_path.display())),
        };
        let v = match json::parse(&text) {
            Ok(v) => v,
            Err(e) => return malformed(format!("parsing {}: {e}", man_path.display())),
        };

        // 1. format version gate — before trusting any other field
        let schema = v.get("schema").and_then(|s| s.as_str()).unwrap_or("");
        if schema != ARTIFACT_SCHEMA {
            return Err(ArtifactError::VersionSkew {
                found: schema.to_string(),
            });
        }

        // 2. structural parse
        let Some(name) = v.get("name").and_then(|s| s.as_str()) else {
            return malformed("missing 'name'");
        };
        let Some(generation) = v.get("generation").and_then(|g| g.as_i64()) else {
            return malformed("missing or non-integer 'generation'");
        };
        if generation < 0 {
            return malformed(format!("negative generation {generation}"));
        }
        let Some(cfg_json) = v.get("config") else {
            return malformed("missing 'config'");
        };
        let config = parse_config(cfg_json)?;
        let dtype = match v.get("dtype").and_then(|s| s.as_str()) {
            None | Some("f32") => Dtype::F32,
            Some("int8") => Dtype::Int8,
            Some(other) => return malformed(format!("unknown dtype '{other}'")),
        };
        let quant = match v.get("quant") {
            None => None,
            Some(q) if q.is_null() => None,
            Some(q) => match QuantSpec::from_json(q) {
                Ok(q) => Some(q),
                Err(e) => return malformed(format!("quant section: {e:#}")),
            },
        };
        if dtype == Dtype::Int8 {
            match &quant {
                None => return malformed("dtype int8 without a baked quant section"),
                Some(q) => {
                    if let Err(e) = q.validate(&config) {
                        return malformed(format!("quant section: {e:#}"));
                    }
                }
            }
        }
        let mut train_metrics = BTreeMap::new();
        if let Some(m) = v.get("train_metrics").and_then(|m| m.as_obj()) {
            for (k, val) in m {
                let Some(f) = val.as_f64() else {
                    return malformed(format!("train_metrics.{k} is not a number"));
                };
                train_metrics.insert(k.clone(), f);
            }
        }
        let Some(table) = v.get("tensors").and_then(|t| t.as_arr()) else {
            return malformed("missing 'tensors' table");
        };

        // tensor table: name → (shape, blob offset, byte_len, digest)
        struct Entry {
            shape: Vec<usize>,
            offset: u64,
            byte_len: u64,
            sha256: String,
        }
        let mut entries: BTreeMap<String, Entry> = BTreeMap::new();
        let mut order: Vec<String> = Vec::with_capacity(table.len());
        let mut offset = 0u64;
        for e in table {
            let Some(tname) = e.get("name").and_then(|s| s.as_str()) else {
                return malformed("tensor entry without a name");
            };
            match e.get("dtype").and_then(|s| s.as_str()) {
                Some("f32") => {}
                other => {
                    return malformed(format!(
                        "tensor '{tname}': unsupported dtype {other:?} (tensor blobs are f32)"
                    ))
                }
            }
            let Some(shape) = e.get("shape").and_then(|s| s.as_arr()) else {
                return malformed(format!("tensor '{tname}': missing shape"));
            };
            let mut dims = Vec::with_capacity(shape.len());
            for d in shape {
                match d.as_usize() {
                    Some(n) => dims.push(n),
                    None => return malformed(format!("tensor '{tname}': bad shape dim")),
                }
            }
            let Some(byte_len) = e.get("byte_len").and_then(|b| b.as_i64()) else {
                return malformed(format!("tensor '{tname}': missing byte_len"));
            };
            if byte_len < 0 {
                return malformed(format!("tensor '{tname}': negative byte_len"));
            }
            let elements: usize = dims.iter().product();
            if byte_len as u64 != 4 * elements as u64 {
                return malformed(format!(
                    "tensor '{tname}': byte_len {byte_len} disagrees with shape {dims:?} \
                     ({} f32 bytes)",
                    4 * elements
                ));
            }
            let Some(digest) = e.get("sha256").and_then(|s| s.as_str()) else {
                return malformed(format!("tensor '{tname}': missing sha256"));
            };
            let digest = digest.to_ascii_lowercase();
            if digest.len() != 64 || !digest.bytes().all(|b| b.is_ascii_hexdigit()) {
                return malformed(format!("tensor '{tname}': sha256 is not 64 hex chars"));
            }
            if entries
                .insert(
                    tname.to_string(),
                    Entry {
                        shape: dims,
                        offset,
                        byte_len: byte_len as u64,
                        sha256: digest,
                    },
                )
                .is_some()
            {
                return malformed(format!("tensor '{tname}' listed twice"));
            }
            order.push(tname.to_string());
            offset += byte_len as u64;
        }
        let total_bytes = offset;

        // 3. inventory vs the declared config — names and shapes must
        // match synth::param_specs exactly (no gaps, no extras)
        let specs = synth::param_specs(&config);
        for spec in &specs {
            let Some(entry) = entries.get(&spec.name) else {
                return Err(ArtifactError::MissingTensor {
                    tensor: spec.name.clone(),
                });
            };
            if entry.shape != spec.shape {
                return malformed(format!(
                    "tensor '{}': shape {:?} disagrees with the config's {:?}",
                    spec.name, entry.shape, spec.shape
                ));
            }
        }
        if entries.len() != specs.len() {
            let known: std::collections::BTreeSet<&str> =
                specs.iter().map(|s| s.name.as_str()).collect();
            let extra: Vec<&String> = order.iter().filter(|n| !known.contains(n.as_str())).collect();
            return malformed(format!("unexpected tensors {extra:?} for the declared config"));
        }

        // 4. whole-blob length — before any per-tensor slicing
        let blob_path = dir.join(WEIGHTS_FILE);
        let blob = match fs::read(&blob_path) {
            Ok(b) => b,
            Err(e) => return malformed(format!("reading {}: {e}", blob_path.display())),
        };
        if blob.len() as u64 != total_bytes {
            return Err(ArtifactError::Truncated {
                want: total_bytes,
                got: blob.len() as u64,
            });
        }

        // 5. per-tensor digests, in blob order
        for tname in &order {
            let entry = &entries[tname];
            let slice = &blob[entry.offset as usize..(entry.offset + entry.byte_len) as usize];
            let got = sha256::hex_digest(slice);
            if got != entry.sha256 {
                return Err(ArtifactError::DigestMismatch {
                    tensor: tname.clone(),
                    want: entry.sha256.clone(),
                    got,
                });
            }
        }

        // 6. everything verified — only now build runtime objects.
        // Weights assemble in canonical spec order whatever the table
        // order; the manifest is reconstructed from the config so state
        // specs and MAC tables cannot skew against the backend.
        let tensors = specs
            .iter()
            .map(|spec| {
                let entry = &entries[&spec.name];
                let slice =
                    &blob[entry.offset as usize..(entry.offset + entry.byte_len) as usize];
                Tensor::new(spec.shape.clone(), f32s_from_le_bytes(slice))
            })
            .collect();
        let mut manifest = synth::manifest(&config, name, 256);
        manifest.dtype = dtype;
        manifest.quant = quant;
        manifest.train_metrics = train_metrics;
        manifest.dir = dir.to_path_buf();
        Ok(Artifact {
            generation: generation as u64,
            manifest,
            weights: Weights { tensors },
        })
    }
}

fn parse_config(v: &Json) -> std::result::Result<ModelConfig, ArtifactError> {
    let usize_arr = |key: &str| -> std::result::Result<Vec<usize>, ArtifactError> {
        let Some(arr) = v.get(key).and_then(|a| a.as_arr()) else {
            return malformed(format!("config.{key}: missing or not an array"));
        };
        let mut out = Vec::with_capacity(arr.len());
        for d in arr {
            match d.as_usize() {
                Some(n) => out.push(n),
                None => return malformed(format!("config.{key}: non-integer entry")),
            }
        }
        Ok(out)
    };
    let req_usize = |key: &str| -> std::result::Result<usize, ArtifactError> {
        match v.get(key).and_then(|n| n.as_usize()) {
            Some(n) => Ok(n),
            None => malformed(format!("config.{key}: missing or not an integer")),
        }
    };
    let channels = usize_arr("channels")?;
    if channels.is_empty() {
        return malformed("config.channels: empty");
    }
    let scc = usize_arr("scc")?;
    let depth = channels.len();
    for &p in &scc {
        if !(1..=depth).contains(&p) {
            return malformed(format!("config.scc position {p} outside 1..={depth}"));
        }
    }
    let shift_pos = v.get("shift_pos").and_then(|j| j.as_usize());
    if let Some(s) = shift_pos {
        if !(1..=depth).contains(&s) {
            return malformed(format!("config.shift_pos {s} outside 1..={depth}"));
        }
    }
    let extrap: Vec<String> = match v.get("extrap").and_then(|a| a.as_arr()) {
        Some(arr) => {
            let mut out = Vec::with_capacity(arr.len());
            for e in arr {
                match e.as_str() {
                    Some(s @ ("duplicate" | "tconv")) => out.push(s.to_string()),
                    other => {
                        return malformed(format!("config.extrap entry {other:?} not duplicate|tconv"))
                    }
                }
            }
            out
        }
        None => vec!["duplicate".to_string(); scc.len()],
    };
    if extrap.len() != scc.len() {
        return malformed(format!(
            "config.extrap lists {} kinds for {} scc positions",
            extrap.len(),
            scc.len()
        ));
    }
    Ok(ModelConfig {
        feat: req_usize("feat")?,
        channels,
        kernel: req_usize("kernel")?,
        scc,
        shift_pos,
        shift: v.get("shift").and_then(|j| j.as_usize()).unwrap_or(1),
        extrap,
        interp: v
            .get("interp")
            .and_then(|j| j.as_str())
            .map(|s| s.to_string()),
    })
}

/// Generation directories under `root`, sorted by ascending generation
/// number: every subdirectory holding an `artifact.json` whose
/// `generation` field parses.  Staging directories (`*.tmp-*`, dot
/// names) and unparsable manifests are skipped rather than failing the
/// listing — a watcher must keep polling past one bad directory (full
/// verification happens at [`Artifact::load`] time, not here).
pub fn list_generations(root: &Path) -> Result<Vec<(u64, PathBuf)>> {
    let mut out = Vec::new();
    let entries =
        fs::read_dir(root).with_context(|| format!("reading {}", root.display()))?;
    for entry in entries {
        let e = entry?;
        let path = e.path();
        let fname = e.file_name().to_string_lossy().to_string();
        if fname.starts_with('.') || fname.contains(".tmp-") || !path.is_dir() {
            continue;
        }
        let man = path.join(MANIFEST_FILE);
        let Ok(text) = fs::read_to_string(&man) else {
            continue;
        };
        let Ok(v) = json::parse(&text) else { continue };
        if v.get("schema").and_then(|s| s.as_str()) != Some(ARTIFACT_SCHEMA) {
            continue;
        }
        let Some(g) = v.get("generation").and_then(|g| g.as_i64()) else {
            continue;
        };
        if g >= 0 {
            out.push((g as u64, path));
        }
    }
    out.sort();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> ModelConfig {
        ModelConfig {
            feat: 4,
            channels: vec![5, 6],
            kernel: 3,
            scc: vec![2],
            shift_pos: None,
            shift: 1,
            extrap: vec!["duplicate".into()],
            interp: None,
        }
    }

    fn tmp_root(tag: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!(
            "soi_artifact_unit_{tag}_{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&p);
        fs::create_dir_all(&p).unwrap();
        p
    }

    fn make(generation: u64) -> Artifact {
        let m = synth::manifest(&small_cfg(), "scc2", 256);
        let w = synth::he_weights(&m, 0xA11CE);
        Artifact::new(m, w, generation).unwrap()
    }

    #[test]
    fn save_load_round_trips() {
        let root = tmp_root("roundtrip");
        let dir = root.join("gen-000003");
        let art = make(3);
        art.save(&dir).unwrap();
        let back = Artifact::load(&dir).unwrap();
        assert_eq!(back.generation, 3);
        assert_eq!(back.name(), "scc2");
        assert_eq!(back.manifest.config, art.manifest.config);
        assert_eq!(back.manifest.params, art.manifest.params);
        for (a, b) in art.weights.tensors.iter().zip(&back.weights.tensors) {
            assert_eq!(a, b);
        }
        // deterministic serialization: re-render is byte-identical
        assert_eq!(art.manifest_json(), back.manifest_json());
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn version_skew_is_typed() {
        let root = tmp_root("skew");
        let dir = root.join("gen-000001");
        make(1).save(&dir).unwrap();
        let man = dir.join(MANIFEST_FILE);
        let text = fs::read_to_string(&man)
            .unwrap()
            .replace(ARTIFACT_SCHEMA, "soi.artifact.v9");
        fs::write(&man, text).unwrap();
        match Artifact::load(&dir) {
            Err(ArtifactError::VersionSkew { found }) => assert_eq!(found, "soi.artifact.v9"),
            other => panic!("expected VersionSkew, got {other:?}"),
        }
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn listing_skips_staging_and_garbage() {
        let root = tmp_root("listing");
        make(2).save(&root.join("gen-000002")).unwrap();
        make(5).save(&root.join("gen-000005")).unwrap();
        // a staging dir and a junk dir must be invisible
        fs::create_dir_all(root.join("gen-000009.tmp-1234")).unwrap();
        fs::write(root.join("gen-000009.tmp-1234").join(MANIFEST_FILE), "{").unwrap();
        fs::create_dir_all(root.join("junk")).unwrap();
        let gens = list_generations(&root).unwrap();
        let seqs: Vec<u64> = gens.iter().map(|(g, _)| *g).collect();
        assert_eq!(seqs, vec![2, 5]);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn generation_is_the_only_varying_field() {
        // same weights at different generations differ only in that field
        let a = make(1).manifest_json();
        let b = make(2).manifest_json();
        assert_ne!(a, b);
        assert_eq!(a.replace("\"generation\": 1", "\"generation\": 2"), b);
    }
}
