//! A minimal wire client: the reference peer for the front-end and
//! shards, used by the integration tests and the `wire-smoke` CLI.
//!
//! [`WireClient::connect`] performs the `Hello` handshake and records
//! the fleet's model shape.  [`WireClient::send`] / [`WireClient::recv`]
//! expose raw messages so fault-injection tests can script exact
//! protocol exchanges; [`WireClient::serve_streams`] drives whole
//! streams through the fleet with the same round-robin interleaving as
//! single-process [`crate::coordinator::Server::run`], so the two
//! paths are bit-comparable.

use std::thread;

use anyhow::{anyhow, bail, Context, Result};

use super::transport::{Transport, WireRead, WireWrite};
use super::wire::{role, write_msg, FrameReader, Msg, WireError, WIRE_VERSION};

/// A connected, greeted wire client.
pub struct WireClient {
    writer: Box<dyn WireWrite>,
    reader: Option<FrameReader<Box<dyn WireRead>>>,
    feat: u32,
    period: u32,
    warmup: u32,
}

impl WireClient {
    /// Dial `transport`, exchange `Hello`s, and record the server's
    /// model shape.  Fails on version skew or a non-hello greeting.
    pub fn connect(transport: &dyn Transport) -> Result<Self> {
        let (r, mut w) = transport.connect().map_err(|e| anyhow!("connect: {e}"))?;
        let hello = Msg::Hello {
            version: WIRE_VERSION,
            role: role::CLIENT,
            feat: 0,
            period: 0,
            warmup: 0,
        };
        write_msg(&mut w, &hello).map_err(|e| anyhow!("hello: {e}"))?;
        let mut reader = FrameReader::new(r);
        let ack = reader
            .next_msg()
            .map_err(|e| anyhow!("handshake: {e}"))?
            .context("server closed during handshake")?;
        let Msg::Hello {
            role: r_role,
            feat,
            period,
            warmup,
            ..
        } = ack
        else {
            bail!("server greeted with {}", ack.kind());
        };
        if r_role != role::FRONT && r_role != role::SHARD {
            bail!("server claims role {r_role}, expected front or shard");
        }
        Ok(WireClient {
            writer: w,
            reader: Some(reader),
            feat,
            period,
            warmup,
        })
    }

    /// Frame width the fleet serves.
    pub fn feat(&self) -> usize {
        self.feat as usize
    }

    /// The fleet's schedule period.
    pub fn period(&self) -> usize {
        self.period as usize
    }

    /// The fleet's §9 replay window, in frames.
    pub fn warmup(&self) -> usize {
        self.warmup as usize
    }

    /// Send one raw message.
    pub fn send(&mut self, msg: &Msg) -> Result<(), WireError> {
        write_msg(&mut self.writer, msg)?;
        Ok(())
    }

    /// Block for the next raw message; `Ok(None)` is a clean close.
    pub fn recv(&mut self) -> Result<Option<Msg>, WireError> {
        self.reader
            .as_mut()
            .expect("reader present between serve_streams calls")
            .next_msg()
    }

    /// Close the write half; the server observes EOF and retires this
    /// connection's sessions.
    pub fn shutdown(&mut self) {
        self.writer.shutdown();
    }

    /// Serve whole streams: stream `i` becomes session `i`, frames are
    /// interleaved round-robin across streams (the same admission
    /// order as single-process serving), and the call returns each
    /// session's outputs in order once every input frame has produced
    /// one.  Any server-side `Err` message fails the call.
    pub fn serve_streams(&mut self, streams: &[Vec<Vec<f32>>]) -> Result<Vec<Vec<Vec<f32>>>> {
        let n = streams.len();
        let expected: usize = streams.iter().map(Vec::len).sum();
        let reader = self.reader.take().expect("reader present");
        let collector = thread::spawn(move || collect_outputs(reader, n, expected));

        let max_len = streams.iter().map(Vec::len).max().unwrap_or(0);
        let mut send_failure = None;
        'send: for i in 0..max_len {
            for (sid, frames) in streams.iter().enumerate() {
                if i >= frames.len() {
                    continue;
                }
                let msg = Msg::Frame {
                    session: sid as u64,
                    seq: i as u64,
                    last: i + 1 == frames.len(),
                    samples: frames[i].clone(),
                    trace: None,
                };
                if let Err(e) = write_msg(&mut self.writer, &msg) {
                    // Keep draining the reader: the server's reply
                    // usually explains the refusal better than a
                    // broken-pipe write error does.
                    send_failure = Some(anyhow!("send: {e}"));
                    break 'send;
                }
            }
        }

        let (reader, outcome) = collector.join().map_err(|_| anyhow!("reader panicked"))?;
        self.reader = Some(reader);
        match outcome {
            Ok(outs) => Ok(outs),
            Err(e) => Err(send_failure.unwrap_or(e)),
        }
    }
}

type TakenReader = FrameReader<Box<dyn WireRead>>;

/// Collect exactly `expected` outputs across `n` sessions, or explain
/// why the stream ended first.
fn collect_outputs(
    mut reader: TakenReader,
    n: usize,
    expected: usize,
) -> (TakenReader, Result<Vec<Vec<Vec<f32>>>>) {
    let mut outs: Vec<Vec<Vec<f32>>> = vec![Vec::new(); n];
    let mut got = 0usize;
    while got < expected {
        match reader.next_msg() {
            Ok(Some(Msg::FrameOut {
                session, samples, ..
            })) => {
                let sid = session as usize;
                if sid >= n {
                    return (reader, Err(anyhow!("output for unknown session {session}")));
                }
                outs[sid].push(samples);
                got += 1;
            }
            Ok(Some(Msg::Err {
                code,
                session,
                detail,
            })) => {
                let e = anyhow!("server error {} on session {session}: {detail}", code.name());
                return (reader, Err(e));
            }
            Ok(Some(other)) => {
                return (reader, Err(anyhow!("unexpected {} mid-serve", other.kind())));
            }
            Ok(None) => {
                let e = anyhow!("server closed after {got} of {expected} outputs");
                return (reader, Err(e));
            }
            Err(e) => return (reader, Err(anyhow!("recv: {e}"))),
        }
    }
    (reader, Ok(outs))
}
