//! A minimal wire client: the reference peer for the front-end and
//! shards, used by the integration tests and the `wire-smoke` CLI.
//!
//! [`WireClient::connect`] performs the `Hello` handshake and records
//! the fleet's model shape.  [`WireClient::send`] / [`WireClient::recv`]
//! expose raw messages so fault-injection tests can script exact
//! protocol exchanges; [`WireClient::serve_streams`] drives whole
//! streams through the fleet with the same round-robin interleaving as
//! single-process [`crate::coordinator::Server::run`], so the two
//! paths are bit-comparable.
//!
//! [`serve_streams_with_retry`] survives connection loss (DESIGN.md
//! §16): it re-dials with exponential backoff and replays every
//! unfinished stream from frame 0 — the server retires a connection's
//! sessions with it, so resume is a cold replay — deduplicating the
//! re-emitted prefix below each stream's high-water mark.
//! Deterministic serving makes the merged outputs bit-identical to an
//! unfaulted run.

use std::thread;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use super::transport::{Transport, WireRead, WireWrite};
use super::wire::{role, write_msg, FrameReader, Msg, WireError, WIRE_VERSION};

/// A connected, greeted wire client.
pub struct WireClient {
    writer: Box<dyn WireWrite>,
    reader: Option<FrameReader<Box<dyn WireRead>>>,
    feat: u32,
    period: u32,
    warmup: u32,
}

impl WireClient {
    /// Dial `transport`, exchange `Hello`s, and record the server's
    /// model shape.  Fails on version skew or a non-hello greeting.
    pub fn connect(transport: &dyn Transport) -> Result<Self> {
        let (r, mut w) = transport.connect().map_err(|e| anyhow!("connect: {e}"))?;
        let hello = Msg::Hello {
            version: WIRE_VERSION,
            role: role::CLIENT,
            feat: 0,
            period: 0,
            warmup: 0,
        };
        write_msg(&mut w, &hello).map_err(|e| anyhow!("hello: {e}"))?;
        let mut reader = FrameReader::new(r);
        let ack = reader
            .next_msg()
            .map_err(|e| anyhow!("handshake: {e}"))?
            .context("server closed during handshake")?;
        let Msg::Hello {
            role: r_role,
            feat,
            period,
            warmup,
            ..
        } = ack
        else {
            bail!("server greeted with {}", ack.kind());
        };
        if r_role != role::FRONT && r_role != role::SHARD {
            bail!("server claims role {r_role}, expected front or shard");
        }
        Ok(WireClient {
            writer: w,
            reader: Some(reader),
            feat,
            period,
            warmup,
        })
    }

    /// Frame width the fleet serves.
    pub fn feat(&self) -> usize {
        self.feat as usize
    }

    /// The fleet's schedule period.
    pub fn period(&self) -> usize {
        self.period as usize
    }

    /// The fleet's §9 replay window, in frames.
    pub fn warmup(&self) -> usize {
        self.warmup as usize
    }

    /// Send one raw message.
    pub fn send(&mut self, msg: &Msg) -> Result<(), WireError> {
        write_msg(&mut self.writer, msg)?;
        Ok(())
    }

    /// Block for the next raw message; `Ok(None)` is a clean close.
    pub fn recv(&mut self) -> Result<Option<Msg>, WireError> {
        self.reader
            .as_mut()
            .expect("reader present between serve_streams calls")
            .next_msg()
    }

    /// Close the write half; the server observes EOF and retires this
    /// connection's sessions.
    pub fn shutdown(&mut self) {
        self.writer.shutdown();
    }

    /// Serve whole streams: stream `i` becomes session `i`, frames are
    /// interleaved round-robin across streams (the same admission
    /// order as single-process serving), and the call returns each
    /// session's outputs in order once every input frame has produced
    /// one.  Any server-side `Err` message fails the call.
    pub fn serve_streams(&mut self, streams: &[Vec<Vec<f32>>]) -> Result<Vec<Vec<Vec<f32>>>> {
        let n = streams.len();
        let expected: usize = streams.iter().map(Vec::len).sum();
        let reader = self.reader.take().expect("reader present");
        let collector = thread::spawn(move || collect_outputs(reader, n, expected));

        let max_len = streams.iter().map(Vec::len).max().unwrap_or(0);
        let mut send_failure = None;
        'send: for i in 0..max_len {
            for (sid, frames) in streams.iter().enumerate() {
                if i >= frames.len() {
                    continue;
                }
                let msg = Msg::Frame {
                    session: sid as u64,
                    seq: i as u64,
                    last: i + 1 == frames.len(),
                    samples: frames[i].clone(),
                    trace: None,
                    deadline_us: None,
                };
                if let Err(e) = write_msg(&mut self.writer, &msg) {
                    // Keep draining the reader: the server's reply
                    // usually explains the refusal better than a
                    // broken-pipe write error does.
                    send_failure = Some(anyhow!("send: {e}"));
                    break 'send;
                }
            }
        }

        let (reader, outcome) = collector.join().map_err(|_| anyhow!("reader panicked"))?;
        self.reader = Some(reader);
        match outcome {
            Ok(outs) => Ok(outs),
            Err(e) => Err(send_failure.unwrap_or(e)),
        }
    }

    /// One recovery attempt for [`serve_streams_with_retry`]: replay
    /// every unfinished stream from frame 0, fold freshly-delivered
    /// outputs into `outs`, and report how the attempt ended.
    fn resume_streams(
        &mut self,
        streams: &[Vec<Vec<f32>>],
        outs: &mut [Vec<Vec<f32>>],
        deadline_us: Option<u64>,
    ) -> Result<Attempt> {
        let n = streams.len();
        // High-water marks: outputs below these are the replayed
        // prefix re-emitting deterministically — expected duplicates.
        let base: Vec<usize> = outs.iter().map(Vec::len).collect();
        let todo: Vec<usize> = (0..n).filter(|&sid| base[sid] < streams[sid].len()).collect();
        let expected_new: usize = todo.iter().map(|&sid| streams[sid].len() - base[sid]).sum();
        if expected_new == 0 {
            return Ok(Attempt::Done);
        }

        let reader = self.reader.take().expect("reader present");
        let collector = {
            let base = base.clone();
            thread::spawn(move || collect_resumed(reader, base, expected_new))
        };

        let max_len = todo.iter().map(|&sid| streams[sid].len()).max().unwrap_or(0);
        'send: for i in 0..max_len {
            for &sid in &todo {
                let frames = &streams[sid];
                if i >= frames.len() {
                    continue;
                }
                let msg = Msg::Frame {
                    session: sid as u64,
                    seq: i as u64,
                    last: i + 1 == frames.len(),
                    samples: frames[i].clone(),
                    trace: None,
                    deadline_us,
                };
                if write_msg(&mut self.writer, &msg).is_err() {
                    // The collector explains the disconnect (or keeps
                    // harvesting outputs the server already emitted).
                    break 'send;
                }
            }
        }

        let (reader, fresh, outcome) = collector.join().map_err(|_| anyhow!("reader panicked"))?;
        self.reader = Some(reader);
        for (sid, mut new) in fresh.into_iter().enumerate() {
            outs[sid].append(&mut new);
        }
        outcome
    }
}

/// How one [`WireClient::resume_streams`] attempt ended.
enum Attempt {
    /// Every expected output is in.
    Done,
    /// The connection died mid-serve; retry with what was harvested.
    Lost,
}

/// Reconnect policy for [`serve_streams_with_retry`].
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Dial attempts (including the first) before giving up.
    pub max_attempts: u32,
    /// First backoff in milliseconds; doubles per failed attempt.
    pub backoff_ms: u64,
    /// Optional recovery deadline declared to the front on every
    /// frame, in microseconds since the session's last delivered
    /// output (DESIGN.md §16).  `None` keeps encodings byte-identical
    /// to plain `soi.wire.v1`.
    pub deadline_us: Option<u64>,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 5,
            backoff_ms: 10,
            deadline_us: None,
        }
    }
}

/// Serve `streams` like [`WireClient::serve_streams`], surviving
/// connection loss: each failed dial or mid-serve disconnect backs
/// off exponentially, re-dials, and replays every unfinished stream
/// from frame 0, deduplicating the re-emitted prefix below each
/// stream's high-water mark.  A typed server `Err` is a refusal, not
/// a fault — it fails fast without retrying.
pub fn serve_streams_with_retry(
    transport: &dyn Transport,
    streams: &[Vec<Vec<f32>>],
    policy: RetryPolicy,
) -> Result<Vec<Vec<Vec<f32>>>> {
    let mut outs: Vec<Vec<Vec<f32>>> = vec![Vec::new(); streams.len()];
    let mut backoff = policy.backoff_ms.max(1);
    let mut last_err = anyhow!("no dial attempted");
    for attempt in 0..policy.max_attempts.max(1) {
        if attempt > 0 {
            thread::sleep(Duration::from_millis(backoff));
            backoff = backoff.saturating_mul(2);
        }
        let mut client = match WireClient::connect(transport) {
            Ok(c) => c,
            Err(e) => {
                last_err = e;
                continue;
            }
        };
        match client.resume_streams(streams, &mut outs, policy.deadline_us) {
            Ok(Attempt::Done) => return Ok(outs),
            Ok(Attempt::Lost) => last_err = anyhow!("connection lost mid-serve"),
            Err(e) => return Err(e),
        }
    }
    Err(last_err.context(format!(
        "gave up after {} attempts",
        policy.max_attempts.max(1)
    )))
}

type TakenReader = FrameReader<Box<dyn WireRead>>;

/// Collect exactly `expected` outputs across `n` sessions, or explain
/// why the stream ended first.
fn collect_outputs(
    mut reader: TakenReader,
    n: usize,
    expected: usize,
) -> (TakenReader, Result<Vec<Vec<Vec<f32>>>>) {
    let mut outs: Vec<Vec<Vec<f32>>> = vec![Vec::new(); n];
    let mut got = 0usize;
    while got < expected {
        match reader.next_msg() {
            Ok(Some(Msg::FrameOut {
                session, samples, ..
            })) => {
                let sid = session as usize;
                if sid >= n {
                    return (reader, Err(anyhow!("output for unknown session {session}")));
                }
                outs[sid].push(samples);
                got += 1;
            }
            Ok(Some(Msg::Err {
                code,
                session,
                detail,
            })) => {
                let e = anyhow!("server error {} on session {session}: {detail}", code.name());
                return (reader, Err(e));
            }
            Ok(Some(other)) => {
                return (reader, Err(anyhow!("unexpected {} mid-serve", other.kind())));
            }
            Ok(None) => {
                let e = anyhow!("server closed after {got} of {expected} outputs");
                return (reader, Err(e));
            }
            Err(e) => return (reader, Err(anyhow!("recv: {e}"))),
        }
    }
    (reader, Ok(outs))
}

/// Collect outputs for a resumed serve until `expected_new` fresh
/// ones arrive: outputs below a session's high-water mark are the
/// deterministic replay of the already-delivered prefix (dropped),
/// the output at the mark is fresh (kept), and any other seq is a
/// protocol violation.  A disconnect ends the attempt retryably with
/// whatever was harvested; a typed server `Err` fails it for good.
fn collect_resumed(
    mut reader: TakenReader,
    base: Vec<usize>,
    expected_new: usize,
) -> (TakenReader, Vec<Vec<Vec<f32>>>, Result<Attempt>) {
    let n = base.len();
    let mut fresh: Vec<Vec<Vec<f32>>> = vec![Vec::new(); n];
    let mut got = 0usize;
    while got < expected_new {
        match reader.next_msg() {
            Ok(Some(Msg::FrameOut {
                session,
                seq,
                samples,
                ..
            })) => {
                let sid = session as usize;
                if sid >= n {
                    let e = anyhow!("output for unknown session {session}");
                    return (reader, fresh, Err(e));
                }
                let s = seq as usize;
                if s < base[sid] {
                    continue; // replayed prefix re-emitting
                }
                let have = base[sid] + fresh[sid].len();
                if s != have {
                    let e = anyhow!("session {session} output seq {seq}, expected {have}");
                    return (reader, fresh, Err(e));
                }
                fresh[sid].push(samples);
                got += 1;
            }
            Ok(Some(Msg::Err {
                code,
                session,
                detail,
            })) => {
                let e = anyhow!("server error {} on session {session}: {detail}", code.name());
                return (reader, fresh, Err(e));
            }
            Ok(Some(other)) => {
                let e = anyhow!("unexpected {} mid-serve", other.kind());
                return (reader, fresh, Err(e));
            }
            Ok(None) => return (reader, fresh, Ok(Attempt::Lost)),
            Err(e)
                if matches!(
                    e,
                    WireError::UnknownTag { .. }
                        | WireError::Malformed { .. }
                        | WireError::VersionSkew { .. }
                ) =>
            {
                // In-band, well-delimited junk: the reader already
                // resynchronized past it; keep collecting.
                continue;
            }
            Err(_) => return (reader, fresh, Ok(Attempt::Lost)),
        }
    }
    (reader, fresh, Ok(Attempt::Done))
}
