//! Production transport: `std::net` TCP, no async runtime.
//!
//! Thin wrappers that map `std::io` failures onto typed
//! [`WireError`]s. `TCP_NODELAY` is set on every stream — the
//! protocol is small-frame and latency-bound, exactly the workload
//! Nagle's algorithm hurts.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::transport::{Duplex, Listener, Transport, WireRead, WireWrite};
use super::wire::WireError;

fn io_err(op: &'static str, e: std::io::Error) -> WireError {
    WireError::Io {
        op,
        detail: e.to_string(),
    }
}

struct TcpRead {
    stream: TcpStream,
}

struct TcpWrite {
    stream: TcpStream,
    down: bool,
}

impl WireRead for TcpRead {
    fn recv(&mut self, out: &mut [u8]) -> Result<usize, WireError> {
        self.stream.read(out).map_err(|e| io_err("read", e))
    }
}

impl WireWrite for TcpWrite {
    fn send(&mut self, bytes: &[u8]) -> Result<(), WireError> {
        if self.down {
            return Err(WireError::Closed);
        }
        self.stream.write_all(bytes).map_err(|e| io_err("write", e))
    }

    fn shutdown(&mut self) {
        if !self.down {
            self.down = true;
            let _ = self.stream.shutdown(std::net::Shutdown::Write);
        }
    }
}

fn split(stream: TcpStream) -> Result<Duplex, WireError> {
    stream.set_nodelay(true).map_err(|e| io_err("nodelay", e))?;
    let writer = stream.try_clone().map_err(|e| io_err("clone", e))?;
    Ok((
        Box::new(TcpRead { stream }),
        Box::new(TcpWrite {
            stream: writer,
            down: false,
        }),
    ))
}

/// TCP dialer for a fixed remote address.
pub struct TcpConnector {
    addr: String,
}

impl TcpConnector {
    /// Connector for `addr` (`host:port`).
    pub fn new(addr: impl Into<String>) -> Self {
        TcpConnector { addr: addr.into() }
    }

    /// The remote address this connector dials.
    pub fn addr(&self) -> &str {
        &self.addr
    }
}

impl Transport for TcpConnector {
    fn connect(&self) -> Result<Duplex, WireError> {
        let addrs = self
            .addr
            .to_socket_addrs()
            .map_err(|e| io_err("resolve", e))?
            .collect::<Vec<_>>();
        let mut last = WireError::Io {
            op: "resolve",
            detail: format!("no addresses for {}", self.addr),
        };
        for a in addrs {
            match TcpStream::connect(a) {
                Ok(s) => return split(s),
                Err(e) => last = io_err("connect", e),
            }
        }
        Err(last)
    }
}

/// Listening TCP endpoint. `close()` is implemented by flipping an
/// atomic flag that the accept loop polls between short
/// `accept`-with-timeout rounds, because `std::net::TcpListener` has
/// no portable cancellable accept.
pub struct TcpPort {
    listener: TcpListener,
    closed: Arc<AtomicBool>,
}

impl TcpPort {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port).
    pub fn bind(addr: &str) -> Result<Self, WireError> {
        let listener = TcpListener::bind(addr).map_err(|e| io_err("bind", e))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| io_err("nonblocking", e))?;
        Ok(TcpPort {
            listener,
            closed: Arc::new(AtomicBool::new(false)),
        })
    }

    /// The bound local address (useful with ephemeral ports).
    pub fn local_addr(&self) -> Result<String, WireError> {
        self.listener
            .local_addr()
            .map(|a| a.to_string())
            .map_err(|e| io_err("local_addr", e))
    }
}

impl Listener for TcpPort {
    fn accept(&self) -> Result<Duplex, WireError> {
        loop {
            if self.closed.load(Ordering::Acquire) {
                return Err(WireError::Closed);
            }
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    stream
                        .set_nonblocking(false)
                        .map_err(|e| io_err("blocking", e))?;
                    return split(stream);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(20));
                }
                Err(e) => return Err(io_err("accept", e)),
            }
        }
    }

    fn close(&self) {
        self.closed.store(true, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::wire::{write_msg, FrameReader, Msg};

    #[test]
    fn tcp_roundtrips_a_frame() {
        let port = TcpPort::bind("127.0.0.1:0").expect("bind");
        let addr = port.local_addr().expect("addr");
        let t = std::thread::spawn(move || {
            let (r, mut w) = port.accept().expect("accept");
            let mut reader = FrameReader::new(r);
            let msg = reader.next_msg().expect("read").expect("msg");
            write_msg(w.as_mut(), &msg).expect("echo");
            w.shutdown();
        });
        let (r, mut w) = TcpConnector::new(addr).connect().expect("connect");
        let sent = Msg::Drain { session: 77 };
        write_msg(w.as_mut(), &sent).expect("send");
        let mut reader = FrameReader::new(r);
        assert_eq!(reader.next_msg().expect("read"), Some(sent));
        t.join().unwrap();
    }

    #[test]
    fn closed_port_stops_accepting() {
        let port = TcpPort::bind("127.0.0.1:0").expect("bind");
        port.close();
        assert!(matches!(port.accept(), Err(WireError::Closed)));
    }
}
