//! Deterministic in-process transport.
//!
//! A [`LoopbackHub`] pairs `connect` calls with `accept` calls over
//! bounded in-memory byte pipes. There are no sockets, no timers and
//! no OS scheduling in the data path, which is what lets the
//! integration tests script byte-level faults reproducibly:
//!
//! * **truncation** — write part of a frame, then [`WireWrite::shutdown`];
//! * **disconnect** — drop both halves mid-stream;
//! * **shard crash** — drop every duplex a fake shard owns;
//! * **backpressure** — build pipes with a small capacity and
//!   `fail_on_full`, so a slow reader surfaces a deterministic
//!   [`WireError::Backpressure`] instead of a timing-dependent stall.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex, PoisonError};

use super::transport::{Duplex, Listener, Transport, WireRead, WireWrite};
use super::wire::{WireError, MAX_FRAME};

/// Default pipe capacity: one max-size frame plus its prefix, so any
/// single well-formed message can be written without blocking.
pub const DEFAULT_PIPE_CAP: usize = MAX_FRAME + 64;

struct PipeState {
    buf: VecDeque<u8>,
    /// Write half closed: reader drains, then sees EOF.
    closed_w: bool,
    /// Read half closed: writes fail with [`WireError::Closed`].
    closed_r: bool,
}

struct PipeInner {
    state: Mutex<PipeState>,
    cv: Condvar,
    cap: usize,
    fail_on_full: bool,
}

/// Read half of an in-memory pipe. Dropping it closes the read side,
/// so a blocked or future writer fails with [`WireError::Closed`].
pub struct PipeReader {
    pipe: Arc<PipeInner>,
}

/// Write half of an in-memory pipe. Dropping it is equivalent to
/// [`WireWrite::shutdown`]: the reader drains what was buffered and
/// then observes EOF.
pub struct PipeWriter {
    pipe: Arc<PipeInner>,
}

/// Create one unidirectional in-memory pipe.
///
/// With `fail_on_full`, a send that does not fit entirely in the
/// remaining capacity fails with [`WireError::Backpressure`] without
/// writing anything — all-or-nothing, so the byte stream is never
/// left mid-frame. Without it, the writer blocks until the reader
/// drains.
pub fn pipe(cap: usize, fail_on_full: bool) -> (PipeReader, PipeWriter) {
    let inner = Arc::new(PipeInner {
        state: Mutex::new(PipeState {
            buf: VecDeque::new(),
            closed_w: false,
            closed_r: false,
        }),
        cv: Condvar::new(),
        cap: cap.max(1),
        fail_on_full,
    });
    (
        PipeReader {
            pipe: Arc::clone(&inner),
        },
        PipeWriter { pipe: inner },
    )
}

impl WireRead for PipeReader {
    fn recv(&mut self, out: &mut [u8]) -> Result<usize, WireError> {
        if out.is_empty() {
            return Ok(0);
        }
        let mut st = self
            .pipe
            .state
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        loop {
            if !st.buf.is_empty() {
                let n = out.len().min(st.buf.len());
                for slot in out.iter_mut().take(n) {
                    *slot = st.buf.pop_front().expect("non-empty");
                }
                self.pipe.cv.notify_all();
                return Ok(n);
            }
            if st.closed_w {
                return Ok(0);
            }
            st = self
                .pipe
                .cv
                .wait(st)
                .unwrap_or_else(PoisonError::into_inner);
        }
    }
}

impl Drop for PipeReader {
    fn drop(&mut self) {
        let mut st = self
            .pipe
            .state
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        st.closed_r = true;
        self.pipe.cv.notify_all();
    }
}

impl WireWrite for PipeWriter {
    fn send(&mut self, bytes: &[u8]) -> Result<(), WireError> {
        let mut pos = 0;
        let mut st = self
            .pipe
            .state
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        while pos < bytes.len() {
            if st.closed_r || st.closed_w {
                return Err(WireError::Closed);
            }
            if self.pipe.fail_on_full {
                if st.buf.len() + (bytes.len() - pos) > self.pipe.cap {
                    return Err(WireError::Backpressure {
                        capacity: self.pipe.cap,
                    });
                }
            } else if st.buf.len() == self.pipe.cap {
                st = self
                    .pipe
                    .cv
                    .wait(st)
                    .unwrap_or_else(PoisonError::into_inner);
                continue;
            }
            let space = self.pipe.cap - st.buf.len();
            let n = space.min(bytes.len() - pos);
            st.buf.extend(&bytes[pos..pos + n]);
            pos += n;
            self.pipe.cv.notify_all();
        }
        Ok(())
    }

    fn shutdown(&mut self) {
        let mut st = self
            .pipe
            .state
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        st.closed_w = true;
        self.pipe.cv.notify_all();
    }
}

impl Drop for PipeWriter {
    fn drop(&mut self) {
        self.shutdown();
    }
}

struct HubState {
    pending: VecDeque<Duplex>,
    closed: bool,
}

struct HubInner {
    state: Mutex<HubState>,
    cv: Condvar,
    cap: usize,
    fail_on_full: bool,
}

/// An in-process rendezvous point: [`Transport::connect`] on one
/// thread pairs with [`Listener::accept`] on another, each side
/// receiving one half of a fresh bidirectional pipe pair. Cloning the
/// hub clones a handle to the same rendezvous.
#[derive(Clone)]
pub struct LoopbackHub {
    inner: Arc<HubInner>,
}

impl LoopbackHub {
    /// Hub with default-capacity blocking pipes.
    pub fn new() -> Self {
        Self::with_pipes(DEFAULT_PIPE_CAP, false)
    }

    /// Hub whose pipes have capacity `cap` and, with `fail_on_full`,
    /// surface [`WireError::Backpressure`] instead of blocking.
    pub fn with_pipes(cap: usize, fail_on_full: bool) -> Self {
        LoopbackHub {
            inner: Arc::new(HubInner {
                state: Mutex::new(HubState {
                    pending: VecDeque::new(),
                    closed: false,
                }),
                cv: Condvar::new(),
                cap,
                fail_on_full,
            }),
        }
    }
}

impl Default for LoopbackHub {
    fn default() -> Self {
        Self::new()
    }
}

impl Transport for LoopbackHub {
    fn connect(&self) -> Result<Duplex, WireError> {
        let (srv_r, cli_w) = pipe(self.inner.cap, self.inner.fail_on_full);
        let (cli_r, srv_w) = pipe(self.inner.cap, self.inner.fail_on_full);
        let mut st = self
            .inner
            .state
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        if st.closed {
            return Err(WireError::Closed);
        }
        st.pending.push_back((Box::new(srv_r), Box::new(srv_w)));
        self.inner.cv.notify_all();
        Ok((Box::new(cli_r), Box::new(cli_w)))
    }
}

impl Listener for LoopbackHub {
    fn accept(&self) -> Result<Duplex, WireError> {
        let mut st = self
            .inner
            .state
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        loop {
            if let Some(d) = st.pending.pop_front() {
                return Ok(d);
            }
            if st.closed {
                return Err(WireError::Closed);
            }
            st = self
                .inner
                .cv
                .wait(st)
                .unwrap_or_else(PoisonError::into_inner);
        }
    }

    fn close(&self) {
        let mut st = self
            .inner
            .state
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        st.closed = true;
        self.inner.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_cross_the_pipe_in_order() {
        let (mut r, mut w) = pipe(8, false);
        let t = std::thread::spawn(move || {
            w.send(&[1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12]).unwrap();
        });
        let mut got = Vec::new();
        let mut buf = [0u8; 5];
        while got.len() < 12 {
            let n = r.recv(&mut buf).unwrap();
            got.extend_from_slice(&buf[..n]);
        }
        t.join().unwrap();
        assert_eq!(got, (1..=12).collect::<Vec<u8>>());
    }

    #[test]
    fn shutdown_yields_eof_after_drain() {
        let (mut r, mut w) = pipe(64, false);
        w.send(&[9, 9]).unwrap();
        w.shutdown();
        let mut buf = [0u8; 8];
        assert_eq!(r.recv(&mut buf).unwrap(), 2);
        assert_eq!(r.recv(&mut buf).unwrap(), 0, "EOF after drain");
        assert_eq!(r.recv(&mut buf).unwrap(), 0, "EOF is sticky");
    }

    #[test]
    fn fail_on_full_is_all_or_nothing() {
        let (mut r, mut w) = pipe(4, true);
        w.send(&[1, 2, 3]).unwrap();
        match w.send(&[4, 5]) {
            Err(WireError::Backpressure { capacity }) => assert_eq!(capacity, 4),
            other => panic!("expected Backpressure, got {other:?}"),
        }
        // Nothing of the failed send leaked into the stream.
        let mut buf = [0u8; 8];
        assert_eq!(r.recv(&mut buf).unwrap(), 3);
        assert_eq!(&buf[..3], &[1, 2, 3]);
    }

    #[test]
    fn dropped_reader_fails_writes() {
        let (r, mut w) = pipe(4, false);
        drop(r);
        assert_eq!(w.send(&[1]), Err(WireError::Closed));
    }

    #[test]
    fn hub_pairs_connect_with_accept() {
        let hub = LoopbackHub::new();
        let server = hub.clone();
        let t = std::thread::spawn(move || {
            let (mut r, mut w) = server.accept().unwrap();
            let mut buf = [0u8; 4];
            let n = r.recv(&mut buf).unwrap();
            w.send(&buf[..n]).unwrap();
        });
        let (mut r, mut w) = hub.connect().unwrap();
        w.send(&[7, 8]).unwrap();
        let mut buf = [0u8; 4];
        assert_eq!(r.recv(&mut buf).unwrap(), 2);
        assert_eq!(&buf[..2], &[7, 8]);
        t.join().unwrap();
    }

    #[test]
    fn closed_hub_rejects_both_sides() {
        let hub = LoopbackHub::new();
        hub.close();
        assert!(matches!(hub.accept(), Err(WireError::Closed)));
        assert!(matches!(hub.connect(), Err(WireError::Closed)));
    }
}
