//! `soi.wire.v1` — the versioned, length-prefixed binary frame
//! protocol spoken between clients, the front-end and shards.
//!
//! Every message on the wire is `[len: u32 LE][tag: u8][payload]`
//! where `len` counts the tag byte plus the payload. All multi-byte
//! integers are little-endian; sample data is IEEE-754 `f32` LE, the
//! same representation the artifact format (DESIGN.md §13) uses, so
//! frames cross the wire bit-exactly.
//!
//! Decoding follows the `ArtifactError` discipline: everything is
//! validated *before* anything is constructed. A failed decode yields
//! exactly one typed [`WireError`] and no partially-decoded [`Msg`];
//! an oversize length prefix is rejected before any body bytes are
//! read or buffered. The full grammar and the fault matrix live in
//! DESIGN.md §14.
//!
//! **Trace context (DESIGN.md §15).** `Frame`, `FrameOut` and
//! `Migrate` optionally carry a 10-byte [`TraceCtx`] as a trailing
//! suffix after their v1 payload. The encoding is strictly additive:
//! with tracing off (the default) nothing is appended and the bytes
//! are identical to plain `soi.wire.v1`, so old peers interop
//! untouched; a traced message reaching an old peer fails its strict
//! length check with the existing typed `Malformed` error — in-band,
//! per-message, never silent.
//!
//! **Liveness + deadlines (DESIGN.md §16).** `Ping`/`Pong` are
//! additive message tags used by the front's heartbeat failure
//! detector; a fleet with heartbeats off never puts them on the wire,
//! so its traffic stays byte-identical to plain v1. `Frame`
//! additionally accepts an optional 8-byte deadline suffix
//! (microseconds of end-to-end recovery budget, nonzero) that
//! composes with the trace suffix: the suffix region after the v1
//! payload is 0, [`DEADLINE_BYTES`], [`TRACE_CTX_BYTES`] or
//! `TRACE_CTX_BYTES + DEADLINE_BYTES` bytes long — all four lengths
//! are distinct, so the decoder discriminates without any flag byte
//! and an absent feature costs zero bytes.

use std::fmt;

use crate::obs::trace::{TraceCtx, TRACE_CTX_BYTES};
use crate::obs::Counter;

/// Schema identifier for this protocol revision.
pub const WIRE_SCHEMA: &str = "soi.wire.v1";

/// Protocol version carried in every [`Msg::Hello`]. Peers with a
/// different version are rejected with [`WireError::VersionSkew`]
/// before any session state exists.
pub const WIRE_VERSION: u16 = 1;

/// Hard ceiling on `tag + payload` length. Anything larger is a
/// protocol violation ([`WireError::Oversize`]) and is rejected from
/// the 4-byte prefix alone — the reader never allocates or consumes
/// the claimed body.
pub const MAX_FRAME: usize = 1 << 20;

/// Peer role carried in [`Msg::Hello`].
pub mod role {
    /// An end client submitting streams.
    pub const CLIENT: u8 = 0;
    /// The front-end (admission + affinity).
    pub const FRONT: u8 = 1;
    /// A backend shard running a worker pool.
    pub const SHARD: u8 = 2;
}

/// Sentinel session id in [`Msg::Drain`] meaning "the whole shard".
pub const DRAIN_ALL: u64 = u64::MAX;

/// Size of the optional deadline suffix on [`Msg::Frame`]: one `u64`
/// LE microsecond budget. Chosen so every suffix-region length
/// (0, 8, 10, 18) is distinct from every other.
pub const DEADLINE_BYTES: usize = 8;

mod tag {
    pub const HELLO: u8 = 1;
    pub const FRAME: u8 = 2;
    pub const FRAME_OUT: u8 = 3;
    pub const MIGRATE: u8 = 4;
    pub const DRAIN: u8 = 5;
    pub const ERR: u8 = 6;
    pub const PING: u8 = 7;
    pub const PONG: u8 = 8;
}

/// Typed decode/transport failure. Mirrors `ArtifactError` (§13):
/// one variant per distinct fault, each carrying enough context to
/// assert on exactly, and never paired with partial output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// Stream ended inside the 4-byte length prefix.
    TruncatedHeader {
        /// Header bytes that did arrive (0..4).
        got: usize,
    },
    /// Stream ended inside the message body.
    TruncatedBody {
        /// Bytes the prefix promised (tag + payload).
        want: usize,
        /// Bytes that actually arrived.
        got: usize,
    },
    /// Length prefix exceeds [`MAX_FRAME`].
    Oversize {
        /// The claimed length.
        len: usize,
        /// The enforced ceiling ([`MAX_FRAME`]).
        max: usize,
    },
    /// Unknown message tag byte.
    UnknownTag {
        /// The offending tag.
        tag: u8,
    },
    /// Peer speaks a different protocol version.
    VersionSkew {
        /// The version the peer announced.
        found: u16,
    },
    /// Structurally invalid payload (bad field values, length
    /// mismatch between the prefix and the fields it frames, …).
    Malformed {
        /// Human-readable description of the violation.
        reason: String,
    },
    /// A bounded pipe was full and the transport is configured to
    /// fail fast instead of blocking (slow-reader backpressure).
    Backpressure {
        /// Pipe capacity in bytes.
        capacity: usize,
    },
    /// The peer closed the connection (clean shutdown observed where
    /// more traffic was required).
    Closed,
    /// An OS-level transport error (TCP only; the loopback transport
    /// never produces this).
    Io {
        /// The operation that failed (`"read"`, `"write"`, …).
        op: &'static str,
        /// Stringified OS error.
        detail: String,
    },
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::TruncatedHeader { got } => {
                write!(f, "truncated header: got {got} of 4 prefix bytes")
            }
            WireError::TruncatedBody { want, got } => {
                write!(f, "truncated body: want {want} bytes, got {got}")
            }
            WireError::Oversize { len, max } => {
                write!(f, "oversize frame: length prefix {len} exceeds max {max}")
            }
            WireError::UnknownTag { tag } => write!(f, "unknown message tag {tag}"),
            WireError::VersionSkew { found } => write!(
                f,
                "version skew: peer speaks v{found}, this end speaks v{WIRE_VERSION}"
            ),
            WireError::Malformed { reason } => write!(f, "malformed message: {reason}"),
            WireError::Backpressure { capacity } => {
                write!(f, "backpressure: pipe full at {capacity} bytes")
            }
            WireError::Closed => write!(f, "connection closed by peer"),
            WireError::Io { op, detail } => write!(f, "io error during {op}: {detail}"),
        }
    }
}

impl std::error::Error for WireError {}

/// Error codes carried in [`Msg::Err`] — the on-wire projection of
/// the faults a peer reports back instead of silently dropping.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrCode {
    /// Handshake rejected: incompatible protocol version.
    VersionSkew,
    /// Admission control refused the new session.
    AdmissionDenied,
    /// A `Frame` violated per-session invariants (seq gap, wrong
    /// feature width).
    BadFrame,
    /// A protocol-level violation on an otherwise healthy connection.
    Protocol,
    /// The shard hosting the session was lost and no survivor could
    /// take it over.
    ShardLost,
    /// The peer is shedding load.
    Backpressure,
    /// Degraded-mode shedding: surviving capacity dropped below
    /// policy, or a session exhausted its retry/deadline budget
    /// during recovery (DESIGN.md §16).
    Overloaded,
}

impl ErrCode {
    /// Wire encoding of the code.
    pub fn as_u16(self) -> u16 {
        match self {
            ErrCode::VersionSkew => 1,
            ErrCode::AdmissionDenied => 2,
            ErrCode::BadFrame => 3,
            ErrCode::Protocol => 4,
            ErrCode::ShardLost => 5,
            ErrCode::Backpressure => 6,
            ErrCode::Overloaded => 7,
        }
    }

    /// Decode a wire code; `None` for values this version does not
    /// know (the caller surfaces [`WireError::Malformed`]).
    pub fn from_u16(v: u16) -> Option<ErrCode> {
        Some(match v {
            1 => ErrCode::VersionSkew,
            2 => ErrCode::AdmissionDenied,
            3 => ErrCode::BadFrame,
            4 => ErrCode::Protocol,
            5 => ErrCode::ShardLost,
            6 => ErrCode::Backpressure,
            7 => ErrCode::Overloaded,
            _ => return None,
        })
    }

    /// Stable lowercase name (used in reports and logs).
    pub fn name(self) -> &'static str {
        match self {
            ErrCode::VersionSkew => "version_skew",
            ErrCode::AdmissionDenied => "admission_denied",
            ErrCode::BadFrame => "bad_frame",
            ErrCode::Protocol => "protocol",
            ErrCode::ShardLost => "shard_lost",
            ErrCode::Backpressure => "backpressure",
            ErrCode::Overloaded => "overloaded",
        }
    }

    /// The per-code telemetry counter (DESIGN.md appendix A): every
    /// wire error a shard or the front *sends* is counted under both
    /// the `wire_errs` total and this per-code breakdown, so a
    /// `VersionSkew` storm is distinguishable from `BadFrame` noise.
    pub fn counter(self) -> Counter {
        match self {
            ErrCode::VersionSkew => Counter::WireErrVersionSkew,
            ErrCode::AdmissionDenied => Counter::WireErrAdmissionDenied,
            ErrCode::BadFrame => Counter::WireErrBadFrame,
            ErrCode::Protocol => Counter::WireErrProtocol,
            ErrCode::ShardLost => Counter::WireErrShardLost,
            ErrCode::Backpressure => Counter::WireErrBackpressure,
            ErrCode::Overloaded => Counter::WireErrOverloaded,
        }
    }
}

/// A fully-decoded `soi.wire.v1` message.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// Handshake, first message in each direction on every
    /// connection. `version` is the *first* payload field so skew is
    /// detectable regardless of what follows it.
    Hello {
        /// Protocol version ([`WIRE_VERSION`]).
        version: u16,
        /// Peer role (see [`role`]).
        role: u8,
        /// Feature width per frame (server fills this in its ack).
        feat: u32,
        /// Schedule period of the serving variant.
        period: u32,
        /// Warmup frames needed for a valid partial-history replay.
        warmup: u32,
    },
    /// One input frame for a session.
    Frame {
        /// Session id.
        session: u64,
        /// Frame counter; must equal the session's next expected seq.
        seq: u64,
        /// True on the final frame of the stream.
        last: bool,
        /// Sample data, `feat` values.
        samples: Vec<f32>,
        /// Optional trace context (DESIGN.md §15); `None` encodes
        /// byte-identically to plain v1.
        trace: Option<TraceCtx>,
        /// Optional end-to-end recovery budget in microseconds
        /// (DESIGN.md §16, nonzero); `None` appends nothing.
        deadline_us: Option<u64>,
    },
    /// One output frame for a session.
    FrameOut {
        /// Session id.
        session: u64,
        /// Seq of the input frame this output answers.
        seq: u64,
        /// Output sample data.
        samples: Vec<f32>,
        /// Optional trace context echoed back by the serving shard.
        trace: Option<TraceCtx>,
    },
    /// Warm-migrate a session onto the receiving shard: resume at
    /// absolute frame counter `t` by replaying `history` through the
    /// §9 path (`history.len() == t` or `>= warmup`).
    Migrate {
        /// Session id.
        session: u64,
        /// Absolute frame counter to resume at.
        t: u64,
        /// Feature width of each history frame.
        feat: u32,
        /// The most recent acked input frames, oldest first.
        history: Vec<Vec<f32>>,
        /// Optional trace context linking the replay to the front's
        /// migration span.
        trace: Option<TraceCtx>,
    },
    /// Retire one session (`session`) or, with [`DRAIN_ALL`], drain
    /// the whole shard and shut it down.
    Drain {
        /// Session id, or [`DRAIN_ALL`].
        session: u64,
    },
    /// A typed error report. `session` is 0 when the error is
    /// connection-scoped rather than session-scoped.
    Err {
        /// What went wrong.
        code: ErrCode,
        /// The affected session, or 0.
        session: u64,
        /// Short human-readable detail.
        detail: String,
    },
    /// Liveness probe (DESIGN.md §16). The front sends one per
    /// heartbeat tick; a shard that stops answering within the miss
    /// budget is declared suspect while its socket is still open.
    Ping {
        /// Monotonic probe counter, echoed back in the [`Msg::Pong`].
        seq: u64,
    },
    /// Liveness probe answer: echoes the probe's `seq` so the sender
    /// can match answers to ticks.
    Pong {
        /// The `seq` of the [`Msg::Ping`] this answers.
        seq: u64,
    },
}

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_f32s(out: &mut Vec<u8>, v: &[f32]) {
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}
/// Append the optional 10-byte trace suffix (DESIGN.md §15); with
/// `None` this appends nothing, keeping the v1 bytes untouched.
fn put_trace(out: &mut Vec<u8>, t: &Option<TraceCtx>) {
    if let Some(t) = t {
        put_u64(out, t.trace_id);
        out.push(t.kind);
        out.push(t.parent);
    }
}

/// Cursor over a fully-received payload. All getters fail with
/// [`WireError::Malformed`] on under-run, so decoders cannot read
/// past the framed length.
struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cur { buf, pos: 0 }
    }
    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8], WireError> {
        if self.buf.len() - self.pos < n {
            return Err(WireError::Malformed {
                reason: format!(
                    "payload too short for {what}: need {n} bytes at offset {}, have {}",
                    self.pos,
                    self.buf.len() - self.pos
                ),
            });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self, what: &str) -> Result<u8, WireError> {
        Ok(self.take(1, what)?[0])
    }
    fn u16(&mut self, what: &str) -> Result<u16, WireError> {
        let b = self.take(2, what)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }
    fn u32(&mut self, what: &str) -> Result<u32, WireError> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
    fn u64(&mut self, what: &str) -> Result<u64, WireError> {
        let b = self.take(8, what)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }
    fn f32s(&mut self, n: usize, what: &str) -> Result<Vec<f32>, WireError> {
        let b = self.take(n * 4, what)?;
        let mut v = Vec::with_capacity(n);
        for c in b.chunks_exact(4) {
            v.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Ok(v)
    }
    fn done(&self, tag_name: &str) -> Result<(), WireError> {
        if self.pos != self.buf.len() {
            return Err(WireError::Malformed {
                reason: format!(
                    "{tag_name}: {} trailing bytes after payload",
                    self.buf.len() - self.pos
                ),
            });
        }
        Ok(())
    }
    /// Consume the optional trailing trace suffix (DESIGN.md §15):
    /// nothing left means untraced, exactly [`TRACE_CTX_BYTES`] left
    /// decodes a [`TraceCtx`], anything else is the same trailing-
    /// bytes violation an untraced v1 decoder reports.
    fn trace(&mut self, tag_name: &str) -> Result<Option<TraceCtx>, WireError> {
        let rem = self.buf.len() - self.pos;
        if rem == 0 {
            return Ok(None);
        }
        if rem != TRACE_CTX_BYTES {
            return Err(WireError::Malformed {
                reason: format!("{tag_name}: {rem} trailing bytes after payload"),
            });
        }
        self.trace_fields(tag_name).map(Some)
    }

    /// Decode exactly one [`TraceCtx`] starting at the cursor.
    fn trace_fields(&mut self, tag_name: &str) -> Result<TraceCtx, WireError> {
        let trace_id = self.u64("trace.id")?;
        let kind = self.u8("trace.kind")?;
        let parent = self.u8("trace.parent")?;
        if trace_id == 0 {
            return Err(WireError::Malformed {
                reason: format!("{tag_name}: trace_id must be nonzero"),
            });
        }
        Ok(TraceCtx {
            trace_id,
            kind,
            parent,
        })
    }

    /// Consume `Frame`'s composed optional suffixes (DESIGN.md §16):
    /// the region after the v1 payload is empty, a deadline
    /// ([`DEADLINE_BYTES`]), a trace ([`TRACE_CTX_BYTES`]), or a
    /// trace followed by a deadline — four pairwise-distinct lengths,
    /// so no flag byte is needed and anything else is the same
    /// trailing-bytes violation a v1 decoder reports.
    fn frame_suffix(
        &mut self,
        tag_name: &str,
    ) -> Result<(Option<TraceCtx>, Option<u64>), WireError> {
        let rem = self.buf.len() - self.pos;
        let (trace, deadline) = match rem {
            0 => (None, None),
            DEADLINE_BYTES => (None, Some(self.u64("frame.deadline")?)),
            TRACE_CTX_BYTES => (Some(self.trace_fields(tag_name)?), None),
            r if r == TRACE_CTX_BYTES + DEADLINE_BYTES => {
                let t = self.trace_fields(tag_name)?;
                (Some(t), Some(self.u64("frame.deadline")?))
            }
            _ => {
                return Err(WireError::Malformed {
                    reason: format!("{tag_name}: {rem} trailing bytes after payload"),
                })
            }
        };
        if deadline == Some(0) {
            return Err(WireError::Malformed {
                reason: format!("{tag_name}: deadline_us must be nonzero"),
            });
        }
        Ok((trace, deadline))
    }
}

impl Msg {
    /// Append the encoded message (prefix + tag + payload) to `out`.
    /// Refuses to produce a frame larger than [`MAX_FRAME`] — the
    /// encoder enforces the same ceiling the decoder does.
    pub fn encode(&self, out: &mut Vec<u8>) -> Result<(), WireError> {
        let start = out.len();
        put_u32(out, 0); // length placeholder
        match self {
            Msg::Hello {
                version,
                role,
                feat,
                period,
                warmup,
            } => {
                out.push(tag::HELLO);
                put_u16(out, *version);
                out.push(*role);
                put_u32(out, *feat);
                put_u32(out, *period);
                put_u32(out, *warmup);
            }
            Msg::Frame {
                session,
                seq,
                last,
                samples,
                trace,
                deadline_us,
            } => {
                out.push(tag::FRAME);
                put_u64(out, *session);
                put_u64(out, *seq);
                out.push(u8::from(*last));
                put_u32(out, samples.len() as u32);
                put_f32s(out, samples);
                put_trace(out, trace);
                if let Some(d) = deadline_us {
                    if *d == 0 {
                        out.truncate(start);
                        return Err(WireError::Malformed {
                            reason: "frame: deadline_us must be nonzero".to_string(),
                        });
                    }
                    put_u64(out, *d);
                }
            }
            Msg::FrameOut {
                session,
                seq,
                samples,
                trace,
            } => {
                out.push(tag::FRAME_OUT);
                put_u64(out, *session);
                put_u64(out, *seq);
                put_u32(out, samples.len() as u32);
                put_f32s(out, samples);
                put_trace(out, trace);
            }
            Msg::Migrate {
                session,
                t,
                feat,
                history,
                trace,
            } => {
                out.push(tag::MIGRATE);
                put_u64(out, *session);
                put_u64(out, *t);
                put_u32(out, history.len() as u32);
                put_u32(out, *feat);
                for frame in history {
                    if frame.len() != *feat as usize {
                        out.truncate(start);
                        return Err(WireError::Malformed {
                            reason: format!(
                                "migrate history frame has {} samples, feat is {feat}",
                                frame.len()
                            ),
                        });
                    }
                    put_f32s(out, frame);
                }
                put_trace(out, trace);
            }
            Msg::Drain { session } => {
                out.push(tag::DRAIN);
                put_u64(out, *session);
            }
            Msg::Err {
                code,
                session,
                detail,
            } => {
                out.push(tag::ERR);
                put_u16(out, code.as_u16());
                put_u64(out, *session);
                let bytes = detail.as_bytes();
                if bytes.len() > u16::MAX as usize {
                    out.truncate(start);
                    return Err(WireError::Malformed {
                        reason: format!("err detail too long: {} bytes", bytes.len()),
                    });
                }
                put_u16(out, bytes.len() as u16);
                out.extend_from_slice(bytes);
            }
            Msg::Ping { seq } => {
                out.push(tag::PING);
                put_u64(out, *seq);
            }
            Msg::Pong { seq } => {
                out.push(tag::PONG);
                put_u64(out, *seq);
            }
        }
        let len = out.len() - start - 4;
        if len > MAX_FRAME {
            out.truncate(start);
            return Err(WireError::Oversize {
                len,
                max: MAX_FRAME,
            });
        }
        out[start..start + 4].copy_from_slice(&(len as u32).to_le_bytes());
        Ok(())
    }

    /// Decode one message from a complete `tag + payload` body (the
    /// length prefix already stripped and bounds-checked by
    /// [`FrameReader`]). Validates everything before constructing the
    /// message; on error nothing of the message escapes.
    pub fn decode(body: &[u8]) -> Result<Msg, WireError> {
        let mut c = Cur::new(body);
        let t = c.u8("tag")?;
        match t {
            tag::HELLO => {
                let version = c.u16("hello.version")?;
                if version != WIRE_VERSION {
                    return Err(WireError::VersionSkew { found: version });
                }
                let role = c.u8("hello.role")?;
                if role > role::SHARD {
                    return Err(WireError::Malformed {
                        reason: format!("hello: unknown role {role}"),
                    });
                }
                let feat = c.u32("hello.feat")?;
                let period = c.u32("hello.period")?;
                let warmup = c.u32("hello.warmup")?;
                c.done("hello")?;
                Ok(Msg::Hello {
                    version,
                    role,
                    feat,
                    period,
                    warmup,
                })
            }
            tag::FRAME => {
                let session = c.u64("frame.session")?;
                let seq = c.u64("frame.seq")?;
                let last = c.u8("frame.last")?;
                if last > 1 {
                    return Err(WireError::Malformed {
                        reason: format!("frame.last must be 0 or 1, got {last}"),
                    });
                }
                let n = c.u32("frame.n")? as usize;
                let samples = c.f32s(n, "frame.samples")?;
                let (trace, deadline_us) = c.frame_suffix("frame")?;
                Ok(Msg::Frame {
                    session,
                    seq,
                    last: last == 1,
                    samples,
                    trace,
                    deadline_us,
                })
            }
            tag::FRAME_OUT => {
                let session = c.u64("frame_out.session")?;
                let seq = c.u64("frame_out.seq")?;
                let n = c.u32("frame_out.n")? as usize;
                let samples = c.f32s(n, "frame_out.samples")?;
                let trace = c.trace("frame_out")?;
                Ok(Msg::FrameOut {
                    session,
                    seq,
                    samples,
                    trace,
                })
            }
            tag::MIGRATE => {
                let session = c.u64("migrate.session")?;
                let t_abs = c.u64("migrate.t")?;
                let h = c.u32("migrate.h")? as usize;
                let feat = c.u32("migrate.feat")?;
                // Validate the framed length up front so a lying
                // header cannot trigger h partial allocations.
                let want = h
                    .checked_mul(feat as usize)
                    .and_then(|n| n.checked_mul(4))
                    .ok_or_else(|| WireError::Malformed {
                        reason: format!("migrate: h={h} x feat={feat} overflows"),
                    })?;
                let rem = body.len() - c.pos;
                if rem != want && rem != want + TRACE_CTX_BYTES {
                    return Err(WireError::Malformed {
                        reason: format!(
                            "migrate: history needs {want} bytes, payload has {rem}"
                        ),
                    });
                }
                let mut history = Vec::with_capacity(h);
                for _ in 0..h {
                    history.push(c.f32s(feat as usize, "migrate.history")?);
                }
                let trace = c.trace("migrate")?;
                Ok(Msg::Migrate {
                    session,
                    t: t_abs,
                    feat,
                    history,
                    trace,
                })
            }
            tag::DRAIN => {
                let session = c.u64("drain.session")?;
                c.done("drain")?;
                Ok(Msg::Drain { session })
            }
            tag::ERR => {
                let raw = c.u16("err.code")?;
                let code = ErrCode::from_u16(raw).ok_or_else(|| WireError::Malformed {
                    reason: format!("err: unknown code {raw}"),
                })?;
                let session = c.u64("err.session")?;
                let dlen = c.u16("err.detail_len")? as usize;
                let bytes = c.take(dlen, "err.detail")?;
                let detail =
                    std::str::from_utf8(bytes).map_err(|_| WireError::Malformed {
                        reason: "err: detail is not valid UTF-8".to_string(),
                    })?;
                c.done("err")?;
                Ok(Msg::Err {
                    code,
                    session,
                    detail: detail.to_string(),
                })
            }
            tag::PING => {
                let seq = c.u64("ping.seq")?;
                c.done("ping")?;
                Ok(Msg::Ping { seq })
            }
            tag::PONG => {
                let seq = c.u64("pong.seq")?;
                c.done("pong")?;
                Ok(Msg::Pong { seq })
            }
            other => Err(WireError::UnknownTag { tag: other }),
        }
    }

    /// Stable lowercase name of the message kind.
    pub fn kind(&self) -> &'static str {
        match self {
            Msg::Hello { .. } => "hello",
            Msg::Frame { .. } => "frame",
            Msg::FrameOut { .. } => "frame_out",
            Msg::Migrate { .. } => "migrate",
            Msg::Drain { .. } => "drain",
            Msg::Err { .. } => "err",
            Msg::Ping { .. } => "ping",
            Msg::Pong { .. } => "pong",
        }
    }
}

use super::transport::{WireRead, WireWrite};

/// Incremental reader: pulls bytes from a [`WireRead`] and yields
/// complete, validated messages. EOF exactly on a message boundary is
/// a clean close (`Ok(None)`); EOF anywhere else is the matching
/// truncation error. An oversize prefix is rejected before any body
/// byte is read.
pub struct FrameReader<R> {
    src: R,
    buf: Vec<u8>,
    /// Bytes of `buf` that are valid (carry-over between reads).
    len: usize,
}

impl<R: WireRead> FrameReader<R> {
    /// Wrap a transport read half.
    pub fn new(src: R) -> Self {
        FrameReader {
            src,
            buf: vec![0u8; 4096],
            len: 0,
        }
    }

    /// Ensure at least `need` buffered bytes, reading as required.
    /// Returns the number of buffered bytes (< `need` iff EOF).
    fn fill(&mut self, need: usize) -> Result<usize, WireError> {
        if self.buf.len() < need {
            self.buf.resize(need, 0);
        }
        while self.len < need {
            let n = self.src.recv(&mut self.buf[self.len..])?;
            if n == 0 {
                break;
            }
            self.len += n;
        }
        Ok(self.len)
    }

    /// Drop `n` consumed bytes from the front of the buffer.
    fn consume(&mut self, n: usize) {
        self.buf.copy_within(n..self.len, 0);
        self.len -= n;
    }

    /// Read the next message. `Ok(None)` on clean EOF at a message
    /// boundary; typed [`WireError`] on any fault.
    pub fn next_msg(&mut self) -> Result<Option<Msg>, WireError> {
        let have = self.fill(4)?;
        if have == 0 {
            return Ok(None);
        }
        if have < 4 {
            let got = have;
            self.len = 0;
            return Err(WireError::TruncatedHeader { got });
        }
        let len = u32::from_le_bytes([self.buf[0], self.buf[1], self.buf[2], self.buf[3]])
            as usize;
        if len > MAX_FRAME {
            self.len = 0;
            return Err(WireError::Oversize {
                len,
                max: MAX_FRAME,
            });
        }
        if len == 0 {
            self.len = 0;
            return Err(WireError::Malformed {
                reason: "zero-length frame (no tag byte)".to_string(),
            });
        }
        let have = self.fill(4 + len)?;
        if have < 4 + len {
            let got = have - 4;
            self.len = 0;
            return Err(WireError::TruncatedBody { want: len, got });
        }
        // The frame is well-delimited even if its body is invalid:
        // consume it either way, so a typed decode error on one
        // message leaves the reader positioned at the next one and
        // the connection's other sessions can keep flowing.
        let res = Msg::decode(&self.buf[4..4 + len]);
        self.consume(4 + len);
        Ok(Some(res?))
    }
}

/// Encode and send one message over a transport write half.
pub fn write_msg<W: WireWrite + ?Sized>(w: &mut W, msg: &Msg) -> Result<usize, WireError> {
    let mut buf = Vec::with_capacity(64);
    msg.encode(&mut buf)?;
    w.send(&buf)?;
    Ok(buf.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(m: &Msg) -> Msg {
        let mut buf = Vec::new();
        m.encode(&mut buf).expect("encode");
        let len =
            u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
        assert_eq!(len, buf.len() - 4, "prefix counts tag+payload");
        Msg::decode(&buf[4..]).expect("decode")
    }

    #[test]
    fn all_message_kinds_roundtrip() {
        let msgs = vec![
            Msg::Hello {
                version: WIRE_VERSION,
                role: role::SHARD,
                feat: 4,
                period: 8,
                warmup: 3,
            },
            Msg::Frame {
                session: 7,
                seq: 42,
                last: true,
                samples: vec![1.0, -2.5, 0.0, f32::MIN_POSITIVE],
                trace: None,
                deadline_us: None,
            },
            Msg::Frame {
                session: 8,
                seq: 1,
                last: false,
                samples: vec![0.25; 3],
                trace: None,
                deadline_us: Some(250_000),
            },
            Msg::FrameOut {
                session: 7,
                seq: 42,
                samples: vec![0.125; 6],
                trace: None,
            },
            Msg::Migrate {
                session: 9,
                t: 16,
                feat: 2,
                history: vec![vec![1.0, 2.0], vec![3.0, 4.0]],
                trace: None,
            },
            Msg::Drain { session: DRAIN_ALL },
            Msg::Err {
                code: ErrCode::AdmissionDenied,
                session: 3,
                detail: "full".to_string(),
            },
            Msg::Err {
                code: ErrCode::Overloaded,
                session: 4,
                detail: "degraded".to_string(),
            },
            Msg::Ping { seq: 17 },
            Msg::Pong { seq: 17 },
        ];
        for m in &msgs {
            assert_eq!(&roundtrip(m), m, "{} roundtrip", m.kind());
        }
    }

    #[test]
    fn empty_frame_payload_roundtrips() {
        let m = Msg::Frame {
            session: 1,
            seq: 0,
            last: false,
            samples: vec![],
            trace: None,
            deadline_us: None,
        };
        assert_eq!(roundtrip(&m), m);
    }

    #[test]
    fn encode_refuses_oversize() {
        let m = Msg::Frame {
            session: 1,
            seq: 0,
            last: false,
            samples: vec![0.0; MAX_FRAME / 4],
            trace: None,
            deadline_us: None,
        };
        let mut buf = Vec::new();
        match m.encode(&mut buf) {
            Err(WireError::Oversize { max, .. }) => assert_eq!(max, MAX_FRAME),
            other => panic!("expected Oversize, got {other:?}"),
        }
        assert!(buf.is_empty(), "failed encode leaves no partial bytes");
    }

    #[test]
    fn version_skew_detected_before_rest_of_hello() {
        let m = Msg::Hello {
            version: WIRE_VERSION,
            role: role::CLIENT,
            feat: 4,
            period: 8,
            warmup: 3,
        };
        let mut buf = Vec::new();
        m.encode(&mut buf).unwrap();
        // Flip the version field (first payload field after the tag)
        // and truncate the rest: skew must still be the error.
        buf[5] = 0x63;
        match Msg::decode(&buf[4..7]) {
            Err(WireError::VersionSkew { found }) => assert_eq!(found, 0x63),
            other => panic!("expected VersionSkew, got {other:?}"),
        }
    }

    #[test]
    fn unknown_tag_is_typed() {
        match Msg::decode(&[0xEE]) {
            Err(WireError::UnknownTag { tag }) => assert_eq!(tag, 0xEE),
            other => panic!("expected UnknownTag, got {other:?}"),
        }
    }

    #[test]
    fn trailing_bytes_are_malformed() {
        let m = Msg::Drain { session: 5 };
        let mut buf = Vec::new();
        m.encode(&mut buf).unwrap();
        buf.push(0);
        match Msg::decode(&buf[4..]) {
            Err(WireError::Malformed { reason }) => {
                assert!(reason.contains("trailing"), "{reason}")
            }
            other => panic!("expected Malformed, got {other:?}"),
        }
    }

    #[test]
    fn migrate_length_must_match_header() {
        let m = Msg::Migrate {
            session: 1,
            t: 2,
            feat: 2,
            history: vec![vec![1.0, 2.0], vec![3.0, 4.0]],
            trace: None,
        };
        let mut buf = Vec::new();
        m.encode(&mut buf).unwrap();
        // Claim 3 history frames while carrying 2.
        let h_off = 4 + 1 + 8 + 8;
        buf[h_off..h_off + 4].copy_from_slice(&3u32.to_le_bytes());
        match Msg::decode(&buf[4..]) {
            Err(WireError::Malformed { reason }) => {
                assert!(reason.contains("history"), "{reason}")
            }
            other => panic!("expected Malformed, got {other:?}"),
        }
    }

    #[test]
    fn trace_suffix_roundtrips_on_every_carrier() {
        use crate::obs::trace::SpanKind;
        let ctx = TraceCtx::root(0xABCD_EF01, SpanKind::FrontAdmit);
        let msgs = vec![
            Msg::Frame {
                session: 3,
                seq: 9,
                last: false,
                samples: vec![0.5, -0.5],
                trace: Some(ctx),
                deadline_us: None,
            },
            Msg::Frame {
                session: 3,
                seq: 10,
                last: false,
                samples: vec![0.5, -0.5],
                trace: Some(ctx),
                deadline_us: Some(1_000_000),
            },
            Msg::FrameOut {
                session: 3,
                seq: 9,
                samples: vec![1.5; 4],
                trace: Some(ctx.child(SpanKind::ShardDispatch)),
            },
            Msg::Migrate {
                session: 3,
                t: 2,
                feat: 2,
                history: vec![vec![1.0, 2.0], vec![3.0, 4.0]],
                trace: Some(TraceCtx::root(7, SpanKind::MigrateFront)),
            },
        ];
        for m in &msgs {
            assert_eq!(&roundtrip(m), m, "traced {} roundtrip", m.kind());
        }
    }

    #[test]
    fn untraced_encoding_is_byte_identical_to_v1() {
        // The additive-suffix contract: `trace: None` must produce
        // exactly the v1 bytes (old peers interop untouched), and the
        // traced twin must differ only by the 10-byte suffix.
        let plain = Msg::Frame {
            session: 1,
            seq: 2,
            last: false,
            samples: vec![1.0, 2.0],
            trace: None,
            deadline_us: None,
        };
        let traced = Msg::Frame {
            session: 1,
            seq: 2,
            last: false,
            samples: vec![1.0, 2.0],
            trace: Some(TraceCtx {
                trace_id: 5,
                kind: 1,
                parent: 0,
            }),
            deadline_us: None,
        };
        let (mut a, mut b) = (Vec::new(), Vec::new());
        plain.encode(&mut a).unwrap();
        traced.encode(&mut b).unwrap();
        assert_eq!(b.len(), a.len() + TRACE_CTX_BYTES);
        // identical after the length prefix, up to the suffix
        assert_eq!(a[4..], b[4..a.len()]);
    }

    #[test]
    fn deadline_off_encoding_is_byte_identical_to_v1() {
        // Same additive contract as the trace suffix (DESIGN.md §16):
        // no deadline appends nothing; a deadline-only frame differs
        // by exactly DEADLINE_BYTES; a trace+deadline frame by
        // exactly TRACE_CTX_BYTES + DEADLINE_BYTES.
        let plain = Msg::Frame {
            session: 1,
            seq: 2,
            last: false,
            samples: vec![1.0, 2.0],
            trace: None,
            deadline_us: None,
        };
        let budgeted = Msg::Frame {
            session: 1,
            seq: 2,
            last: false,
            samples: vec![1.0, 2.0],
            trace: None,
            deadline_us: Some(500_000),
        };
        let both = Msg::Frame {
            session: 1,
            seq: 2,
            last: false,
            samples: vec![1.0, 2.0],
            trace: Some(TraceCtx {
                trace_id: 5,
                kind: 1,
                parent: 0,
            }),
            deadline_us: Some(500_000),
        };
        let (mut a, mut b, mut c) = (Vec::new(), Vec::new(), Vec::new());
        plain.encode(&mut a).unwrap();
        budgeted.encode(&mut b).unwrap();
        both.encode(&mut c).unwrap();
        assert_eq!(b.len(), a.len() + DEADLINE_BYTES);
        assert_eq!(c.len(), a.len() + TRACE_CTX_BYTES + DEADLINE_BYTES);
        assert_eq!(a[4..], b[4..a.len()], "v1 prefix of the budgeted frame");
        assert_eq!(a[4..], c[4..a.len()], "v1 prefix of the traced+budgeted frame");
        assert_eq!(roundtrip(&budgeted), budgeted);
        assert_eq!(roundtrip(&both), both);
    }

    #[test]
    fn bad_deadline_suffixes_are_malformed() {
        let m = Msg::Frame {
            session: 1,
            seq: 0,
            last: false,
            samples: vec![1.0],
            trace: None,
            deadline_us: None,
        };
        // A zero deadline is reserved (absent-deadline sentinel) —
        // rejected symmetrically by encoder and decoder.
        let bad = Msg::Frame {
            session: 1,
            seq: 0,
            last: false,
            samples: vec![1.0],
            trace: None,
            deadline_us: Some(0),
        };
        let mut buf = Vec::new();
        match bad.encode(&mut buf) {
            Err(WireError::Malformed { reason }) => {
                assert!(reason.contains("nonzero"), "{reason}")
            }
            other => panic!("expected Malformed, got {other:?}"),
        }
        assert!(buf.is_empty(), "failed encode leaves no partial bytes");
        let mut buf = Vec::new();
        m.encode(&mut buf).unwrap();
        buf.extend_from_slice(&[0u8; DEADLINE_BYTES]);
        match Msg::decode(&buf[4..]) {
            Err(WireError::Malformed { reason }) => {
                assert!(reason.contains("nonzero"), "{reason}")
            }
            other => panic!("expected Malformed, got {other:?}"),
        }
        // A suffix region matching none of the four lengths is the
        // v1 trailing-bytes violation (here: 10 + 8 + 1 = 19 bytes).
        let mut buf = Vec::new();
        m.encode(&mut buf).unwrap();
        buf.extend_from_slice(&[1u8; TRACE_CTX_BYTES + DEADLINE_BYTES + 1]);
        match Msg::decode(&buf[4..]) {
            Err(WireError::Malformed { reason }) => {
                assert!(reason.contains("trailing"), "{reason}")
            }
            other => panic!("expected Malformed, got {other:?}"),
        }
    }

    #[test]
    fn ping_pong_are_fixed_size_and_trailing_checked() {
        let mut buf = Vec::new();
        Msg::Ping { seq: 3 }.encode(&mut buf).unwrap();
        assert_eq!(buf.len(), 4 + 1 + 8, "ping is prefix + tag + seq");
        buf.push(0);
        match Msg::decode(&buf[4..]) {
            Err(WireError::Malformed { reason }) => {
                assert!(reason.contains("trailing"), "{reason}")
            }
            other => panic!("expected Malformed, got {other:?}"),
        }
    }

    #[test]
    fn bad_trace_suffixes_are_malformed() {
        let m = Msg::Frame {
            session: 1,
            seq: 0,
            last: false,
            samples: vec![1.0],
            trace: None,
            deadline_us: None,
        };
        // wrong suffix length: not absent, not a deadline, not a
        // trace, not both
        let mut buf = Vec::new();
        m.encode(&mut buf).unwrap();
        buf.extend_from_slice(&[0u8; 3]);
        match Msg::decode(&buf[4..]) {
            Err(WireError::Malformed { reason }) => {
                assert!(reason.contains("trailing"), "{reason}")
            }
            other => panic!("expected Malformed, got {other:?}"),
        }
        // a zero trace id is reserved (absent-trace sentinel)
        let mut buf = Vec::new();
        m.encode(&mut buf).unwrap();
        buf.extend_from_slice(&[0u8; TRACE_CTX_BYTES]);
        match Msg::decode(&buf[4..]) {
            Err(WireError::Malformed { reason }) => {
                assert!(reason.contains("nonzero"), "{reason}")
            }
            other => panic!("expected Malformed, got {other:?}"),
        }
    }

    #[test]
    fn err_codes_map_to_distinct_counters() {
        let mut seen = std::collections::HashSet::new();
        for code in [
            ErrCode::VersionSkew,
            ErrCode::AdmissionDenied,
            ErrCode::BadFrame,
            ErrCode::Protocol,
            ErrCode::ShardLost,
            ErrCode::Backpressure,
            ErrCode::Overloaded,
        ] {
            assert!(seen.insert(code.counter().name()), "{:?} counter reused", code);
        }
    }

    #[test]
    fn err_code_names_roundtrip() {
        for code in [
            ErrCode::VersionSkew,
            ErrCode::AdmissionDenied,
            ErrCode::BadFrame,
            ErrCode::Protocol,
            ErrCode::ShardLost,
            ErrCode::Backpressure,
            ErrCode::Overloaded,
        ] {
            assert_eq!(ErrCode::from_u16(code.as_u16()), Some(code));
            assert!(!code.name().is_empty());
        }
        assert_eq!(ErrCode::from_u16(0), None);
        assert_eq!(ErrCode::from_u16(999), None);
    }
}
