//! L4 wire layer — sharded serving over a byte-stream transport.
//!
//! One `soi` process is a deep but single-OS-process serving stack;
//! scaling to "millions of users" (ROADMAP item 1) needs a wire. This
//! module adds exactly that, without giving up the determinism the
//! rest of the crate is built on:
//!
//! * [`wire`] — `soi.wire.v1`: a versioned, length-prefixed binary
//!   frame protocol (Hello/Frame/FrameOut/Migrate/Drain/Err) with
//!   typed decode errors in the `ArtifactError` discipline — a decode
//!   failure never yields a partially-constructed message or session.
//! * [`transport`] — the [`Transport`]/[`Listener`] abstraction over
//!   byte-stream duplexes, so every component above it is transport-
//!   agnostic.
//! * [`loopback`] — a deterministic in-process transport with bounded
//!   pipes and scriptable faults (truncation, disconnect, fail-fast
//!   backpressure) used by the fault-matrix integration tests.
//! * [`tcp`] — the production transport: thin std-only wrappers over
//!   `std::net` (no async runtime, consistent with the crate's
//!   offline, dependency-free posture).
//! * [`shard`] — a backend shard: one `coordinator::server` worker
//!   pool behind a wire endpoint, with warm resume of migrated
//!   streams via the §9 replay path.
//! * [`front`] — the front-end: admission control, session→shard
//!   affinity, zero-drop cross-shard warm migration, and shard-loss
//!   recovery by replaying acked history on a survivor.
//! * [`balance`] — the cluster-level sibling of
//!   `coordinator::LoadController`: pure rebalancing decisions from
//!   per-shard `soi.obs.v1` health feeds.
//! * [`client`] — a minimal blocking client used by the smoke
//!   subcommand and the integration tests, with deadline-budgeted
//!   reconnect-and-replay recovery ([`serve_streams_with_retry`]).
//! * [`chaos`] — a deterministic fault-injection proxy: seeded
//!   kill/stall/partition/corrupt plans executed on frame-boundary
//!   ticks, with exact drop accounting, driving the survival tests
//!   and the `chaos-smoke` subcommand.
//!
//! DESIGN.md §14 documents the frame grammar, the shard lifecycle and
//! the fault-matrix semantics; §16 covers liveness, rejoin and the
//! chaos-plan format.

pub mod balance;
pub mod chaos;
pub mod client;
pub mod front;
pub mod loopback;
pub mod shard;
pub mod tcp;
pub mod transport;
pub mod wire;

pub use balance::{health_from_feed, ClusterController, ClusterDecision, ClusterPolicy, ShardHealth};
pub use chaos::{chaos_wrap, ChaosFleet, ChaosPlan, ChaosReport, ChaosSwitch, Fault, PlannedFault};
pub use client::{serve_streams_with_retry, RetryPolicy, WireClient};
pub use front::{spawn_front, spawn_front_with, FrontHandle, FrontPolicy, FrontReport, ShardLink};
pub use loopback::LoopbackHub;
pub use shard::{run_shard, ShardConfig, ShardReport};
pub use tcp::{TcpConnector, TcpPort};
pub use transport::{Duplex, Listener, Transport, WireRead, WireWrite};
pub use wire::{ErrCode, FrameReader, Msg, WireError, MAX_FRAME, WIRE_SCHEMA, WIRE_VERSION};
