//! Transport abstraction: blocking byte-stream duplexes.
//!
//! Everything above this layer (front-end, shards, clients) is
//! written against these traits, so the deterministic loopback
//! transport used by the fault-matrix tests and the production TCP
//! transport are interchangeable.

use super::wire::WireError;

/// Blocking read half of a duplex byte stream.
pub trait WireRead: Send {
    /// Read up to `out.len()` bytes. `Ok(0)` means EOF (peer closed
    /// its write half). Blocks until at least one byte is available,
    /// EOF, or a transport fault.
    fn recv(&mut self, out: &mut [u8]) -> Result<usize, WireError>;
}

/// Blocking write half of a duplex byte stream.
pub trait WireWrite: Send {
    /// Write all of `bytes` or fail. A bounded transport configured
    /// to fail fast returns [`WireError::Backpressure`] instead of
    /// blocking when the peer reads too slowly.
    fn send(&mut self, bytes: &[u8]) -> Result<(), WireError>;

    /// Close the write half; the peer's reader observes EOF after
    /// draining buffered bytes. Idempotent.
    fn shutdown(&mut self);
}

impl WireRead for Box<dyn WireRead> {
    fn recv(&mut self, out: &mut [u8]) -> Result<usize, WireError> {
        (**self).recv(out)
    }
}

impl WireWrite for Box<dyn WireWrite> {
    fn send(&mut self, bytes: &[u8]) -> Result<(), WireError> {
        (**self).send(bytes)
    }

    fn shutdown(&mut self) {
        (**self).shutdown();
    }
}

/// A connected duplex: independently-owned read and write halves.
pub type Duplex = (Box<dyn WireRead>, Box<dyn WireWrite>);

/// Client side of a transport: dial an endpoint.  `Sync` because a
/// front-end retains the transport to re-dial lost shards from
/// rejoin helper threads while the router still owns the handle.
pub trait Transport: Send + Sync {
    /// Establish a new duplex to the endpoint.
    fn connect(&self) -> Result<Duplex, WireError>;
}

/// Server side of a transport: accept inbound duplexes.  `Sync`
/// because accept and close race by design (a controller thread
/// closes a listener the acceptor thread is blocked on).
pub trait Listener: Send + Sync {
    /// Block until the next inbound connection. Returns
    /// [`WireError::Closed`] once [`Listener::close`] is called.
    fn accept(&self) -> Result<Duplex, WireError>;

    /// Unblock pending and future [`Listener::accept`] calls with
    /// [`WireError::Closed`]. Idempotent; takes `&self` so a
    /// controller thread can close a listener another thread is
    /// accepting on.
    fn close(&self);
}
