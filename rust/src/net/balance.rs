//! Cluster-level load balancing: the fleet sibling of
//! [`crate::coordinator::LoadController`].
//!
//! Where the per-worker controller moves its streams *down a ladder*
//! (cheaper variants) under overload, the [`ClusterController`] moves
//! streams *across shards*: it observes one [`ShardHealth`] per shard
//! — distilled from each shard's `soi.obs.v1` NDJSON health feed by
//! [`health_from_feed`] — and, with the same patience/cooldown
//! hysteresis discipline, nominates one stream migration from the
//! hottest shard to the calmest.  The decision is pure logic; the
//! front-end executes it with a zero-drop warm migration
//! (DESIGN.md §14).
//!
//! Like the worker controller after its recover-side fix, the
//! cooldown gate runs *before* any patience accrual, so patience can
//! only be earned from observations made outside the cooldown window.

use crate::util::json::{self, Json};
use crate::util::stats::Histogram;

/// One shard's distilled health, as the cluster controller sees it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardHealth {
    /// Shard index (position in the front-end's shard table).
    pub shard: usize,
    /// False once the front-end lost the shard's connection.
    pub reachable: bool,
    /// Live streams on the shard ([`crate::obs::Gauge::StreamsLive`]).
    pub streams: u64,
    /// Backlog after the latest round ([`crate::obs::Gauge::QueueDepth`]).
    pub queue_depth: u64,
    /// p99 exec wall time, µs, over the shard's merged exec histograms.
    pub p99_us: u64,
}

/// Hysteresis thresholds for [`ClusterController`].  Mirrors
/// [`crate::coordinator::AdaptivePolicy`]'s shape: pressure and calm
/// bars, patience before acting, cooldown after.
#[derive(Debug, Clone, Copy)]
pub struct ClusterPolicy {
    /// Backlog at or above which a shard counts as hot.
    pub queue_high: u64,
    /// Backlog at or below which a shard can accept a stream.
    pub queue_low: u64,
    /// Minimum stream-count gap (hot minus calm) before moving; stops
    /// the controller ping-ponging a single stream between shards.
    pub imbalance_min: u64,
    /// Consecutive hot observations required before a migration.
    pub patience: u32,
    /// Observations ignored after each decision (the migration itself
    /// perturbs both shards; judging it immediately double-triggers).
    pub cooldown: u32,
}

impl Default for ClusterPolicy {
    fn default() -> Self {
        ClusterPolicy {
            queue_high: 8,
            queue_low: 1,
            imbalance_min: 2,
            patience: 3,
            cooldown: 4,
        }
    }
}

/// A nominated cross-shard stream migration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ClusterDecision {
    /// Shard to take a stream from (the hot one).
    pub from: usize,
    /// Shard to move it to (the calm one).
    pub to: usize,
    /// The hot shard's backlog at decision time.
    pub backlog: u64,
    /// The hot shard's p99 exec µs at decision time.
    pub p99_us: u64,
}

/// The cluster rebalancer.  Call [`ClusterController::observe`] once
/// per health-poll tick; it returns at most one decision, then holds
/// its cooldown.
#[derive(Debug)]
pub struct ClusterController {
    policy: ClusterPolicy,
    hot_rounds: u32,
    cooldown_left: u32,
}

impl ClusterController {
    /// A controller over `policy`.
    pub fn new(policy: ClusterPolicy) -> Self {
        ClusterController {
            policy,
            hot_rounds: 0,
            cooldown_left: 0,
        }
    }

    /// The active policy.
    pub fn policy(&self) -> &ClusterPolicy {
        &self.policy
    }

    /// One observation of the fleet.  Returns a migration nomination
    /// when the hottest reachable shard has held `queue_high` backlog
    /// for `patience` consecutive observations while some other
    /// reachable shard sits at or below `queue_low` with at least
    /// `imbalance_min` fewer streams.  During cooldown nothing is
    /// observed at all — patience restarts from zero afterwards.
    pub fn observe(&mut self, shards: &[ShardHealth]) -> Option<ClusterDecision> {
        if self.cooldown_left > 0 {
            self.cooldown_left -= 1;
            self.hot_rounds = 0;
            return None;
        }
        let hot = shards
            .iter()
            .filter(|s| s.reachable)
            .max_by_key(|s| (s.queue_depth, s.p99_us))?;
        let calm = shards
            .iter()
            .filter(|s| s.reachable && s.shard != hot.shard)
            .min_by_key(|s| (s.queue_depth, s.streams))?;
        let pressured = hot.queue_depth >= self.policy.queue_high && hot.streams > 0;
        let room = calm.queue_depth <= self.policy.queue_low
            && hot.streams >= calm.streams + self.policy.imbalance_min;
        if pressured && room {
            self.hot_rounds += 1;
            if self.hot_rounds >= self.policy.patience {
                self.hot_rounds = 0;
                self.cooldown_left = self.policy.cooldown;
                return Some(ClusterDecision {
                    from: hot.shard,
                    to: calm.shard,
                    backlog: hot.queue_depth,
                    p99_us: hot.p99_us,
                });
            }
        } else {
            self.hot_rounds = 0;
        }
        None
    }
}

/// Distill one shard's `soi.obs.v1` NDJSON feed into a
/// [`ShardHealth`]: gauges come from the latest `snapshot` record,
/// and p99 from the latest-seq `exec_ns` `hist` records re-ingested
/// bucket by bucket ([`Histogram::add_bucket`]) and merged — exact,
/// because the feed exports the histogram's own log-linear buckets.
/// Lines that fail to parse are skipped (a live feed's last line may
/// be mid-write); an empty or snapshot-less feed is an error.
pub fn health_from_feed(shard: usize, text: &str) -> Result<ShardHealth, String> {
    fn get_u64(v: &Json, key: &str) -> Option<u64> {
        v.get(key).and_then(Json::as_f64).map(|f| f as u64)
    }
    let mut best_seq: Option<u64> = None;
    let mut streams = 0u64;
    let mut queue_depth = 0u64;
    // (seq, bucket idx, count) of every exec_ns hist line
    let mut hist_lines: Vec<(u64, usize, u64)> = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let Ok(v) = json::parse(line) else { continue };
        let Some(ty) = v.get("type").and_then(|t| t.as_str()) else {
            continue;
        };
        let seq = get_u64(&v, "seq").unwrap_or(0);
        match ty {
            "snapshot" => {
                if seq >= best_seq.unwrap_or(0) {
                    best_seq = Some(seq);
                    if let Some(g) = v.get("gauges") {
                        streams = get_u64(g, "streams_live").unwrap_or(0);
                        queue_depth = get_u64(g, "queue_depth").unwrap_or(0);
                    }
                }
            }
            "hist" => {
                if v.get("name").and_then(|n| n.as_str()) == Some("exec_ns") {
                    if let Some(buckets) = v.get("buckets").and_then(Json::as_arr) {
                        for b in buckets {
                            let Some(pair) = b.as_arr() else { continue };
                            if pair.len() == 2 {
                                if let (Some(i), Some(c)) = (
                                    pair[0].as_usize(),
                                    pair[1].as_f64().map(|f| f as u64),
                                ) {
                                    hist_lines.push((seq, i, c));
                                }
                            }
                        }
                    }
                }
            }
            _ => {}
        }
    }
    let Some(latest) = best_seq else {
        return Err(format!("shard {shard}: feed has no snapshot record"));
    };
    // Feed histograms are cumulative; the latest seq's records are the
    // totals.  (Hist records only render at seqs with exec activity,
    // so take the newest seq that has any, not `latest` itself.)
    let mut p99_us = 0u64;
    if let Some(hseq) = hist_lines.iter().map(|(s, _, _)| *s).max() {
        let mut h = Histogram::new();
        for &(s, i, c) in &hist_lines {
            if s == hseq {
                h.add_bucket(i, c);
            }
        }
        p99_us = h.p99() / 1000;
    }
    Ok(ShardHealth {
        shard,
        reachable: true,
        streams,
        queue_depth,
        p99_us,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fleet(hot_q: u64, calm_q: u64) -> Vec<ShardHealth> {
        vec![
            ShardHealth {
                shard: 0,
                reachable: true,
                streams: 6,
                queue_depth: hot_q,
                p99_us: 900,
            },
            ShardHealth {
                shard: 1,
                reachable: true,
                streams: 2,
                queue_depth: calm_q,
                p99_us: 100,
            },
        ]
    }

    fn quick() -> ClusterPolicy {
        ClusterPolicy {
            queue_high: 4,
            queue_low: 1,
            imbalance_min: 2,
            patience: 2,
            cooldown: 3,
        }
    }

    #[test]
    fn patience_gates_the_first_decision() {
        let mut c = ClusterController::new(quick());
        assert_eq!(c.observe(&fleet(8, 0)), None, "patience 1 of 2");
        let d = c.observe(&fleet(8, 0)).expect("fires at patience");
        assert_eq!((d.from, d.to), (0, 1));
        assert_eq!(d.backlog, 8);
    }

    #[test]
    fn cooldown_blocks_and_resets_patience() {
        let mut c = ClusterController::new(quick());
        c.observe(&fleet(8, 0));
        c.observe(&fleet(8, 0)).expect("decision");
        // cooldown 3: nothing fires, and patience earned inside the
        // window is discarded
        for i in 0..3 {
            assert_eq!(c.observe(&fleet(9, 0)), None, "cooldown round {i}");
        }
        assert_eq!(c.observe(&fleet(9, 0)), None, "patience restarts at 0");
        assert!(c.observe(&fleet(9, 0)).is_some(), "fresh patience earned");
    }

    #[test]
    fn calm_fleet_never_moves() {
        let mut c = ClusterController::new(quick());
        for _ in 0..10 {
            assert_eq!(c.observe(&fleet(1, 0)), None);
        }
    }

    #[test]
    fn no_room_on_target_blocks_the_move() {
        let mut c = ClusterController::new(quick());
        for _ in 0..10 {
            // both shards backed up: nowhere to move to
            assert_eq!(c.observe(&fleet(8, 5)), None);
        }
    }

    #[test]
    fn unreachable_shards_are_invisible() {
        let mut c = ClusterController::new(quick());
        let mut shards = fleet(8, 0);
        shards[1].reachable = false;
        for _ in 0..10 {
            assert_eq!(c.observe(&shards), None, "no reachable target");
        }
    }

    #[test]
    fn imbalance_floor_prevents_ping_pong() {
        let mut c = ClusterController::new(quick());
        let mut shards = fleet(8, 0);
        shards[0].streams = 3;
        shards[1].streams = 2; // gap 1 < imbalance_min 2
        for _ in 0..10 {
            assert_eq!(c.observe(&shards), None);
        }
    }

    #[test]
    fn health_distills_a_real_feed() {
        use crate::obs::{take_snapshot, Gauge, ObsConfig, Telemetry};
        let tel = Telemetry::new(ObsConfig { ring_capacity: 64 });
        let h = tel.worker(0);
        for _ in 0..200 {
            h.exec(0, 1, 2, 1_000_000); // 1 ms
        }
        h.with(|w| {
            w.gauge_set(Gauge::StreamsLive, 5);
            w.gauge_set(Gauge::QueueDepth, 3);
        });
        let mut out = String::new();
        take_snapshot(&tel).render_ndjson(0, 0, &mut out);
        let hh = health_from_feed(2, &out).expect("feed distills");
        assert_eq!(hh.shard, 2);
        assert!(hh.reachable);
        assert_eq!(hh.streams, 5);
        assert_eq!(hh.queue_depth, 3);
        // log-linear buckets: p99 lands in the 1 ms bucket's bound
        assert!(
            hh.p99_us >= 900 && hh.p99_us <= 1200,
            "p99_us = {}",
            hh.p99_us
        );
    }

    #[test]
    fn snapshotless_feed_is_an_error() {
        assert!(health_from_feed(0, "").is_err());
        assert!(health_from_feed(0, "not json\n").is_err());
    }
}
