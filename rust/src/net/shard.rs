//! A backend shard: one `coordinator::server` worker pool behind a
//! wire endpoint (DESIGN.md §14).
//!
//! The shard accepts one front-end connection at a time, exchanges
//! `Hello`s (rejecting version skew before any session state exists),
//! then bridges the wire and a live worker pool
//! ([`crate::coordinator::Server::start_live`]): `Frame` → worker,
//! worker output → `FrameOut`, `Migrate` → §9 replay admission,
//! `Drain` → session retirement (or, with [`super::wire::DRAIN_ALL`],
//! graceful shard shutdown).  Per-session faults answer with a typed
//! `Err` message and touch nothing else; losing the front-end
//! connection drops every session (the front re-creates them by
//! replay elsewhere) and loops back to `accept`.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread;

use anyhow::{anyhow, Result};

use super::transport::{Duplex, Listener, WireWrite};
use super::wire::{role, write_msg, ErrCode, FrameReader, Msg, WireError, DRAIN_ALL, WIRE_VERSION};
use crate::coordinator::{FrameJob, LiveCmd, LiveEvent, Server};
use crate::obs::{Counter, Gauge, ObsHandle, SpanKind};
use crate::runtime::warmup_frames;

/// Shard-process configuration.
#[derive(Debug, Clone, Copy)]
pub struct ShardConfig {
    /// Operator-assigned 1-based shard id, exported as
    /// [`Gauge::ShardId`] so the cluster controller can attribute the
    /// shard's health feed (0 = unsharded).
    pub shard_id: u64,
}

/// What [`run_shard`] counted over its lifetime.
#[derive(Debug, Clone, Copy, Default)]
pub struct ShardReport {
    /// Front-end connections served.
    pub conns: u64,
    /// Input frames accepted onto workers.
    pub frames_in: u64,
    /// Output frames written to the wire.
    pub frames_out: u64,
    /// Sessions admitted by §9 replay (`Migrate`).
    pub resumes: u64,
    /// Sessions retired by `Drain`.
    pub drains: u64,
    /// Typed wire faults observed (decode errors, rejected resumes,
    /// mid-stream protocol violations).
    pub wire_errs: u64,
}

/// One event on the shard's unified queue: either something the wire
/// produced or something a worker produced.
enum ConnEvent {
    Wire(Result<Option<Msg>, WireError>),
    Live(LiveEvent),
}

/// After a decode error, can the byte stream still be trusted?  The
/// frame is well-delimited for in-band faults (unknown tag, malformed
/// body, skewed hello), so the reader keeps going; truncation and
/// oversize mean framing itself is lost.
fn survivable(e: &WireError) -> bool {
    matches!(
        e,
        WireError::UnknownTag { .. } | WireError::Malformed { .. } | WireError::VersionSkew { .. }
    )
}

fn count(obs: &Option<ObsHandle>, c: Counter, n: u64) {
    if let Some(h) = obs {
        h.count(c, n);
    }
}

/// Run a shard until [`Listener::close`] or a whole-shard `Drain`.
/// `server` supplies the worker pool configuration (ladder, batching,
/// adaptive policy, telemetry, reload) exactly as single-process
/// serving does.
pub fn run_shard(
    server: &Server,
    listener: &dyn Listener,
    cfg: ShardConfig,
) -> Result<ShardReport> {
    let obs = server.telemetry.as_ref().map(|t| t.shared());
    if let Some(h) = &obs {
        h.with(|w| w.gauge_set(Gauge::ShardId, cfg.shard_id));
    }
    let feat = server.ladder().level(0).manifest.config.feat as u32;
    let period = server.ladder().level(0).manifest.period as u32;
    let warmup = warmup_frames(&server.ladder().level(0).manifest.config) as u32;

    let mut report = ShardReport::default();
    loop {
        let conn = match listener.accept() {
            Ok(d) => d,
            Err(WireError::Closed) => return Ok(report),
            Err(e) => return Err(anyhow!("shard accept failed: {e}")),
        };
        report.conns += 1;
        match serve_conn(server, conn, (feat, period, warmup), &obs, &mut report)? {
            ConnEnd::FrontGone => continue,
            ConnEnd::DrainAll => return Ok(report),
        }
    }
}

enum ConnEnd {
    /// The front-end disconnected; every session died with it.
    FrontGone,
    /// Whole-shard drain requested: exit gracefully.
    DrainAll,
}

fn serve_conn(
    server: &Server,
    conn: Duplex,
    (feat, period, warmup): (u32, u32, u32),
    obs: &Option<ObsHandle>,
    report: &mut ShardReport,
) -> Result<ConnEnd> {
    let (reader_half, mut w) = conn;

    // Unified event queue: a reader thread forwards wire messages, a
    // pump thread forwards worker events; this thread owns the writer.
    let (tx, rx) = channel::<ConnEvent>();
    let reader_tx = tx.clone();
    let reader_thread = thread::spawn(move || {
        let mut reader = FrameReader::new(reader_half);
        loop {
            let item = reader.next_msg();
            let fatal = match &item {
                Ok(None) => true,
                Ok(Some(_)) => false,
                Err(e) => !survivable(e),
            };
            if reader_tx.send(ConnEvent::Wire(item)).is_err() || fatal {
                return;
            }
        }
    });

    // Handshake: the front speaks first.  Version skew (or anything
    // else malformed) is rejected before any worker state exists.
    match rx.recv() {
        Ok(ConnEvent::Wire(Ok(Some(Msg::Hello { version: _, role: r, .. })))) => {
            if r != role::FRONT && r != role::CLIENT {
                let _ = send_err(&mut w, obs, ErrCode::Protocol, 0, "expected front hello");
                report.wire_errs += 1;
                w.shutdown();
                let _ = reader_thread.join();
                return Ok(ConnEnd::FrontGone);
            }
        }
        Ok(ConnEvent::Wire(Err(WireError::VersionSkew { found }))) => {
            report.wire_errs += 1;
            let _ = send_err(
                &mut w,
                obs,
                ErrCode::VersionSkew,
                0,
                &format!("shard speaks v{WIRE_VERSION}, peer sent v{found}"),
            );
            w.shutdown();
            let _ = reader_thread.join();
            return Ok(ConnEnd::FrontGone);
        }
        _ => {
            report.wire_errs += 1;
            let _ = send_err(&mut w, obs, ErrCode::Protocol, 0, "handshake failed");
            w.shutdown();
            let _ = reader_thread.join();
            return Ok(ConnEnd::FrontGone);
        }
    }
    let ack = Msg::Hello {
        version: WIRE_VERSION,
        role: role::SHARD,
        feat,
        period,
        warmup,
    };
    if send_msg(&mut w, obs, &ack).is_err() {
        w.shutdown();
        let _ = reader_thread.join();
        return Ok(ConnEnd::FrontGone);
    }

    // The worker pool lives exactly as long as the connection: if the
    // front goes away, so does every session it owned here (the front
    // re-creates them elsewhere by §9 replay).
    let mut live = server.start_live();
    let ev_rx = live.take_events().expect("fresh pool");
    let pump_tx: Sender<ConnEvent> = tx;
    let pump_thread = thread::spawn(move || {
        for ev in ev_rx {
            if pump_tx.send(ConnEvent::Live(ev)).is_err() {
                return;
            }
        }
    });

    // Per-session expected next input seq (admission bookkeeping only;
    // the authoritative frame counter lives in the worker's session).
    let mut next_seq: HashMap<u64, u64> = HashMap::new();
    let mut end = ConnEnd::FrontGone;
    let mut fatal: Option<anyhow::Error> = None;

    for ev in &rx {
        match ev {
            ConnEvent::Wire(Ok(Some(msg))) => {
                count(obs, Counter::WireRxMsgs, 1);
                match msg {
                    Msg::Frame {
                        session,
                        seq,
                        last,
                        samples,
                        trace,
                        // The deadline is the front's recovery
                        // contract; a shard ignores it.
                        deadline_us: _,
                    } => {
                        if samples.len() != feat as usize {
                            report.wire_errs += 1;
                            let detail =
                                format!("frame has {} samples, feat is {feat}", samples.len());
                            if send_err(&mut w, obs, ErrCode::BadFrame, session, &detail).is_err() {
                                break;
                            }
                            continue;
                        }
                        let want = next_seq.entry(session).or_insert(0);
                        if seq != *want {
                            report.wire_errs += 1;
                            let detail = format!("frame seq {seq}, expected {want}");
                            if send_err(&mut w, obs, ErrCode::BadFrame, session, &detail).is_err() {
                                break;
                            }
                            continue;
                        }
                        *want += 1;
                        report.frames_in += 1;
                        // traced frame: open shard_dispatch under the
                        // front's span, forward the child context to
                        // the worker (DESIGN.md §15)
                        let job_trace = trace.map(|ctx| {
                            if let Some(h) = obs {
                                h.span(
                                    ctx.trace_id,
                                    SpanKind::ShardDispatch,
                                    ctx.kind,
                                    session,
                                    seq,
                                    0,
                                );
                            }
                            ctx.child(SpanKind::ShardDispatch)
                        });
                        live.submit(LiveCmd::Frame(FrameJob {
                            stream_id: session,
                            frame: Arc::from(samples.as_slice()),
                            last,
                            trace: job_trace,
                        }))?;
                    }
                    Msg::Migrate {
                        session,
                        t,
                        feat: mfeat,
                        history,
                        trace,
                    } => {
                        if mfeat != feat {
                            report.wire_errs += 1;
                            let detail = format!("migrate feat {mfeat}, shard serves {feat}");
                            if send_err(&mut w, obs, ErrCode::Protocol, session, &detail).is_err() {
                                break;
                            }
                            continue;
                        }
                        next_seq.insert(session, t);
                        report.resumes += 1;
                        // the worker records the migrate_replay span
                        // when (and only when) the replay succeeds
                        live.submit(LiveCmd::Resume {
                            stream_id: session,
                            t,
                            history,
                            trace,
                        })?;
                    }
                    Msg::Drain { session } => {
                        if session == DRAIN_ALL {
                            end = ConnEnd::DrainAll;
                            break;
                        }
                        next_seq.remove(&session);
                        report.drains += 1;
                        live.submit(LiveCmd::Forget { stream_id: session })?;
                    }
                    Msg::Ping { seq } => {
                        // Liveness probe (DESIGN.md §16): answer in
                        // arrival order so a pong proves the shard's
                        // wire loop is still draining.
                        if send_msg(&mut w, obs, &Msg::Pong { seq }).is_err() {
                            break;
                        }
                    }
                    Msg::Pong { .. } => {
                        // Shards never probe; a stray pong is noise.
                    }
                    Msg::Hello { .. } | Msg::FrameOut { .. } => {
                        report.wire_errs += 1;
                        if send_err(&mut w, obs, ErrCode::Protocol, 0, "unexpected message")
                            .is_err()
                        {
                            break;
                        }
                    }
                    Msg::Err { .. } => {
                        // The front reporting back; note it, serve on.
                        report.wire_errs += 1;
                        count(obs, Counter::WireErrs, 1);
                    }
                }
            }
            ConnEvent::Wire(Ok(None)) => break, // front closed cleanly
            ConnEvent::Wire(Err(e)) => {
                report.wire_errs += 1;
                count(obs, Counter::WireErrs, 1);
                if !survivable(&e)
                    || send_err(&mut w, obs, ErrCode::Protocol, 0, &e.to_string()).is_err()
                {
                    break; // framing lost — the connection is dead
                }
            }
            ConnEvent::Live(LiveEvent::Out {
                id,
                seq,
                frame,
                trace,
            }) => {
                report.frames_out += 1;
                let out = Msg::FrameOut {
                    session: id,
                    seq,
                    samples: frame,
                    trace,
                };
                if send_msg(&mut w, obs, &out).is_err() {
                    break;
                }
            }
            ConnEvent::Live(LiveEvent::Retired { id, .. }) => {
                next_seq.remove(&id);
            }
            ConnEvent::Live(LiveEvent::ResumeFailed { id, reason }) => {
                // The replay constructed nothing; report and forget.
                report.wire_errs += 1;
                next_seq.remove(&id);
                if send_err(&mut w, obs, ErrCode::Protocol, id, &reason).is_err() {
                    break;
                }
            }
            ConnEvent::Live(LiveEvent::Fatal { reason }) => {
                fatal = Some(anyhow!("shard worker died: {reason}"));
                break;
            }
        }
    }

    live.shutdown()?;
    w.shutdown();
    drop(rx);
    let _ = pump_thread.join();
    let _ = reader_thread.join();
    if let Some(e) = fatal {
        return Err(e);
    }
    Ok(end)
}

/// Write one message, counting it.  A `Err` return means the peer is
/// gone (or refuses the bytes); the caller ends the connection rather
/// than the shard.
fn send_msg(
    w: &mut Box<dyn WireWrite>,
    obs: &Option<ObsHandle>,
    msg: &Msg,
) -> Result<(), WireError> {
    let n = write_msg(w.as_mut(), msg)?;
    if let Some(h) = obs {
        h.with(|o| {
            o.count(Counter::WireTxMsgs, 1);
            o.count(Counter::WireTxBytes, n as u64);
        });
    }
    Ok(())
}

/// Send a typed error, counting it under both the [`Counter::WireErrs`]
/// total and the code's own `wire_err_*` counter (additive schema
/// change — DESIGN.md appendix A).
fn send_err(
    w: &mut Box<dyn WireWrite>,
    obs: &Option<ObsHandle>,
    code: ErrCode,
    session: u64,
    detail: &str,
) -> Result<(), WireError> {
    if let Some(h) = obs {
        h.with(|o| {
            o.count(Counter::WireErrs, 1);
            o.count(code.counter(), 1);
        });
    }
    send_msg(
        w,
        obs,
        &Msg::Err {
            code,
            session,
            detail: detail.to_string(),
        },
    )
}
