//! Deterministic chaos harness (DESIGN.md §16).
//!
//! A [`ChaosSwitch`] interposes a frame-aligned proxy between the
//! front-end and one shard: the front dials the proxy, the proxy
//! dials the real shard, and every `soi.wire.v1` frame crossing it is
//! forwarded whole — so injected faults land on frame boundaries and
//! the harness can count exactly what it dropped.  Faults are the
//! failure modes the survival layer must absorb:
//!
//! * [`Fault::Kill`] — sever the bridged connection; new dials (the
//!   front's rejoin attempts) queue until [`Fault::Heal`];
//! * [`Fault::Stall`] — keep the connection open but withhold
//!   shard→front frames, flushing them in order on heal (exercises
//!   the suspect verdict and the front's stale-output drop);
//! * [`Fault::Partition`] — silently discard frames in both
//!   directions while staying connected (grey failure: writes
//!   succeed, nothing arrives, and new dials hang like dropped SYNs
//!   until heal);
//! * [`Fault::CorruptSurvivable`] / [`Fault::CorruptFatal`] — inject
//!   junk into the shard→front stream: a well-delimited unknown-tag
//!   frame the reader resynchronizes past, or an oversize length
//!   prefix that destroys framing and costs the shard connection.
//!
//! Faults fire two ways: scripted directly through a switch
//! ([`ChaosSwitch::apply`]) at points a test controls, or scheduled
//! by a seeded [`ChaosPlan`] in *ticks*.  The tick clock is
//! fleet-global: one tick per front→shard frame crossing *any* proxy
//! (inputs, replays and heartbeat pings all advance it), so a plan's
//! timing is tied to protocol progress, not wall clock — and a heal
//! scheduled for a killed shard still fires, carried by the traffic
//! the survivors keep serving.  The same seed always yields the same
//! plan.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::thread;

use super::loopback::LoopbackHub;
use super::transport::{Listener, Transport, WireRead, WireWrite};
use super::wire::MAX_FRAME;
use crate::util::rng::Rng;

/// One failure mode a [`ChaosSwitch`] can apply.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Sever the bridged connection; rejoin dials queue until heal.
    Kill,
    /// Withhold shard→front frames; they flush, in order, on heal.
    Stall,
    /// Silently discard frames in both directions, staying connected.
    Partition,
    /// Inject one well-delimited unknown-tag frame (reader survives).
    CorruptSurvivable,
    /// Inject an oversize length prefix (framing lost, connection dies).
    CorruptFatal,
    /// Clear every fault and flush anything stalled.
    Heal,
}

/// One scheduled fault: apply `fault` to shard `shard`'s switch once
/// the fleet-global clock reaches `tick`.
#[derive(Debug, Clone, Copy)]
pub struct PlannedFault {
    /// Which shard's switch fires.
    pub shard: usize,
    /// Global front→shard frame count that triggers it.
    pub tick: u64,
    /// What to do.
    pub fault: Fault,
}

/// A fault schedule over a fleet, globally tick-ordered.
#[derive(Debug, Clone, Default)]
pub struct ChaosPlan {
    faults: Vec<PlannedFault>,
}

impl ChaosPlan {
    /// A plan from explicit faults (sorted into firing order).
    pub fn new(mut faults: Vec<PlannedFault>) -> Self {
        faults.sort_by_key(|f| (f.tick, f.shard));
        ChaosPlan { faults }
    }

    /// A seeded pseudo-random plan: `events` fault→heal episodes over
    /// `shards` shards, each lasting up to `span` ticks.  Episodes
    /// never overlap — at most one shard is faulted at a time — so
    /// the fleet always keeps serving capacity, survivor traffic
    /// keeps the global clock advancing, and every scheduled heal is
    /// guaranteed to fire.  The survival invariants (every accepted
    /// frame answered or typed-errored, survivors bit-identical) stay
    /// decidable under any seed.
    pub fn seeded(seed: u64, shards: usize, span: u64, events: usize) -> Self {
        assert!(shards > 0, "plan needs at least one shard");
        let span = span.max(4);
        let mut rng = Rng::new(seed);
        let mut faults = Vec::with_capacity(events * 2);
        let mut cursor = 0u64;
        for _ in 0..events {
            let shard = rng.below(shards);
            let at = cursor + 2 + rng.next_u64() % span;
            let fault = match rng.below(4) {
                0 => Fault::Kill,
                1 => Fault::Stall,
                2 => Fault::Partition,
                _ => Fault::CorruptSurvivable,
            };
            faults.push(PlannedFault {
                shard,
                tick: at,
                fault,
            });
            // Heal well past the front's miss budget worth of pings.
            let heal_at = at + 4 + rng.next_u64() % span;
            faults.push(PlannedFault {
                shard,
                tick: heal_at,
                fault: Fault::Heal,
            });
            cursor = heal_at;
        }
        ChaosPlan::new(faults)
    }

    /// The scheduled `(tick, fault)` pairs for one shard, tick-ordered.
    pub fn for_shard(&self, shard: usize) -> Vec<(u64, Fault)> {
        self.faults
            .iter()
            .filter(|f| f.shard == shard)
            .map(|f| (f.tick, f.fault))
            .collect()
    }

    /// Every scheduled fault, in firing order.
    pub fn faults(&self) -> &[PlannedFault] {
        &self.faults
    }
}

/// What one switch did over its lifetime — the harness's ground truth
/// for exact drop accounting.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ChaosReport {
    /// Front→shard frames this switch observed.
    pub ticks: u64,
    /// Frames discarded by kill/partition (both directions).
    pub dropped: u64,
    /// Junk injections into the shard→front stream.
    pub injected: u64,
    /// Connections bridged (1 + successful rejoins through this proxy).
    pub bridges: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Mode {
    Normal,
    Stalled,
    Partitioned,
    Killed,
}

type SharedWriter = Arc<Mutex<Box<dyn WireWrite>>>;
type SwitchInner = Arc<(Mutex<SwitchState>, Condvar)>;

struct SwitchState {
    mode: Mode,
    /// Buffered shard→front frames while stalled.
    stalled: VecDeque<Vec<u8>>,
    /// Current bridge's write halves (None before the first bridge or
    /// after a kill).
    front_w: Option<SharedWriter>,
    shard_w: Option<SharedWriter>,
    report: ChaosReport,
}

/// The fleet-shared plan executor: a global tick clock plus the not-
/// yet-fired tail of the plan.  Any switch's front→shard pump
/// advances the clock and fires every due entry, whichever switch it
/// targets — so a killed shard's heal rides on survivor traffic.
struct Scheduler {
    clock: AtomicU64,
    queue: Mutex<VecDeque<PlannedFault>>,
    /// One entry per shard, filled once all switches exist.
    targets: Mutex<Vec<SwitchInner>>,
}

impl Scheduler {
    /// Advance the global clock by one frame and fire due entries.
    fn advance(&self) {
        let now = self.clock.fetch_add(1, Ordering::SeqCst) + 1;
        loop {
            let due = {
                let mut q = self.queue.lock().unwrap_or_else(PoisonError::into_inner);
                match q.front() {
                    Some(f) if f.tick <= now => q.pop_front(),
                    _ => None,
                }
            };
            let Some(f) = due else { return };
            let target = {
                let targets = self.targets.lock().unwrap_or_else(PoisonError::into_inner);
                targets.get(f.shard).cloned()
            };
            if let Some(t) = target {
                let mut st = lock(&t);
                apply_fault(&mut st, f.fault);
                drop(st);
                t.1.notify_all();
            }
        }
    }
}

/// Scripting handle for one shard's chaos proxy.  Clonable; a test
/// keeps one per shard and the proxy threads share the state.
#[derive(Clone)]
pub struct ChaosSwitch {
    inner: SwitchInner,
    /// The front-facing hub, kept so [`ChaosSwitch::close`] can stop
    /// the accept loop.
    hub: LoopbackHub,
}

fn lock(inner: &SwitchInner) -> std::sync::MutexGuard<'_, SwitchState> {
    inner.0.lock().unwrap_or_else(PoisonError::into_inner)
}

impl ChaosSwitch {
    /// Apply one fault now, regardless of the tick clock.
    pub fn apply(&self, fault: Fault) {
        let mut st = lock(&self.inner);
        apply_fault(&mut st, fault);
        drop(st);
        self.inner.1.notify_all();
    }

    /// Snapshot the switch's accounting.
    pub fn report(&self) -> ChaosReport {
        lock(&self.inner).report
    }

    /// Stop accepting new bridges and sever the current one.
    pub fn close(&self) {
        self.apply(Fault::Kill);
        self.hub.close();
    }
}

/// A fleet of chaos proxies sharing one tick clock and one plan.
pub struct ChaosFleet {
    switches: Vec<ChaosSwitch>,
    sched: Arc<Scheduler>,
}

impl ChaosFleet {
    /// Interpose a chaos proxy in front of every shard transport,
    /// executing `plan` on the shared clock.  Returns the hubs the
    /// front-end should dial (index-aligned with `shards`) and the
    /// fleet handle.
    pub fn wrap(
        shards: Vec<Arc<dyn Transport>>,
        plan: &ChaosPlan,
    ) -> (Vec<LoopbackHub>, ChaosFleet) {
        let sched = Arc::new(Scheduler {
            clock: AtomicU64::new(0),
            queue: Mutex::new(plan.faults().iter().copied().collect()),
            targets: Mutex::new(Vec::new()),
        });
        let mut hubs = Vec::with_capacity(shards.len());
        let mut switches = Vec::with_capacity(shards.len());
        for shard in shards {
            let (hub, switch) = wrap_one(shard, Arc::clone(&sched));
            sched
                .targets
                .lock()
                .unwrap_or_else(PoisonError::into_inner)
                .push(Arc::clone(&switch.inner));
            hubs.push(hub);
            switches.push(switch);
        }
        (hubs, ChaosFleet { switches, sched })
    }

    /// The scripting handle for shard `i`'s switch.
    pub fn switch(&self, i: usize) -> &ChaosSwitch {
        &self.switches[i]
    }

    /// Per-switch accounting, index-aligned with the wrapped shards.
    pub fn reports(&self) -> Vec<ChaosReport> {
        self.switches.iter().map(|s| s.report()).collect()
    }

    /// The global tick clock (total front→shard frames observed).
    pub fn ticks(&self) -> u64 {
        self.sched.clock.load(Ordering::SeqCst)
    }

    /// Plan entries that never fired (clock stopped short of them).
    pub fn unfired(&self) -> usize {
        self.sched
            .queue
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .len()
    }

    /// Close every switch.
    pub fn close(&self) {
        for s in &self.switches {
            s.close();
        }
    }
}

/// Interpose a single chaos proxy in front of `shard`, with its own
/// private clock executing `plan` (ticks = this shard's front→shard
/// frames).  Returns the transport the front-end should dial and the
/// switch scripting the faults.  For multi-shard fleets prefer
/// [`ChaosFleet::wrap`]: a private clock freezes while its shard is
/// killed, so a kill here should be healed by script, not by plan.
pub fn chaos_wrap(
    shard: Arc<dyn Transport>,
    plan: Vec<(u64, Fault)>,
) -> (LoopbackHub, ChaosSwitch) {
    let sched = Arc::new(Scheduler {
        clock: AtomicU64::new(0),
        queue: Mutex::new(
            plan.into_iter()
                .map(|(tick, fault)| PlannedFault {
                    shard: 0,
                    tick,
                    fault,
                })
                .collect(),
        ),
        targets: Mutex::new(Vec::new()),
    });
    let (hub, switch) = wrap_one(shard, Arc::clone(&sched));
    sched
        .targets
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
        .push(Arc::clone(&switch.inner));
    (hub, switch)
}

fn wrap_one(shard: Arc<dyn Transport>, sched: Arc<Scheduler>) -> (LoopbackHub, ChaosSwitch) {
    let hub = LoopbackHub::new();
    let switch = ChaosSwitch {
        inner: Arc::new((
            Mutex::new(SwitchState {
                mode: Mode::Normal,
                stalled: VecDeque::new(),
                front_w: None,
                shard_w: None,
                report: ChaosReport::default(),
            }),
            Condvar::new(),
        )),
        hub: hub.clone(),
    };
    let accept_hub = hub.clone();
    let inner = Arc::clone(&switch.inner);
    thread::spawn(move || accept_loop(accept_hub, shard, inner, sched));
    (hub, switch)
}

/// Apply `fault` with the state locked.  Writer shutdowns take the
/// writer lock *inside* the state lock — the pumps take them in the
/// same order, so this cannot deadlock.
fn apply_fault(st: &mut SwitchState, fault: Fault) {
    match fault {
        Fault::Kill => {
            st.mode = Mode::Killed;
            // Severing the write halves is what the peers observe:
            // the front's reader sees EOF (shard loss), the shard
            // sees FrontGone and loops back to accept.
            for w in [st.front_w.take(), st.shard_w.take()].into_iter().flatten() {
                w.lock().unwrap_or_else(PoisonError::into_inner).shutdown();
            }
            st.report.dropped += st.stalled.len() as u64;
            st.stalled.clear();
        }
        Fault::Stall => {
            // On a killed switch this (like Partition) only re-arms
            // acceptance; there is no connection to stall yet.
            st.mode = Mode::Stalled;
        }
        Fault::Partition => st.mode = Mode::Partitioned,
        Fault::CorruptSurvivable => {
            // One well-delimited frame with an unknown tag: the
            // reader reports it and resynchronizes at the next frame.
            inject(st, &[1, 0, 0, 0, 0xEE]);
        }
        Fault::CorruptFatal => {
            // An oversize length prefix: framing is lost for good.
            inject(st, &((MAX_FRAME as u32 + 1).to_le_bytes()));
        }
        Fault::Heal => {
            st.mode = Mode::Normal;
            // Flush everything withheld, in arrival order.
            if let Some(w) = st.front_w.clone() {
                let mut w = w.lock().unwrap_or_else(PoisonError::into_inner);
                while let Some(frame) = st.stalled.pop_front() {
                    if w.send(&frame).is_err() {
                        st.report.dropped += 1 + st.stalled.len() as u64;
                        st.stalled.clear();
                        break;
                    }
                }
            }
        }
    }
}

fn inject(st: &mut SwitchState, junk: &[u8]) {
    if st.mode == Mode::Killed {
        return;
    }
    if let Some(w) = st.front_w.clone() {
        let mut w = w.lock().unwrap_or_else(PoisonError::into_inner);
        if w.send(junk).is_ok() {
            st.report.injected += 1;
        }
    }
}

/// Accept front connections forever (initial dial + every rejoin),
/// bridging each to a fresh connection to the real shard.  While
/// killed or partitioned, accepted connections wait unbridged —
/// exactly a dead or unreachable endpoint — and proceed on heal.
fn accept_loop(
    hub: LoopbackHub,
    shard: Arc<dyn Transport>,
    inner: SwitchInner,
    sched: Arc<Scheduler>,
) {
    loop {
        let (front_r, front_w) = match hub.accept() {
            Ok(d) => d,
            Err(_) => return,
        };
        // Hold the dial while killed or partitioned: a dead endpoint
        // accepts nothing, and a partition that swallowed the dial's
        // handshake would otherwise wedge the front's one in-flight
        // rejoin attempt forever — holding the bridge until heal is
        // what a real dropped-SYN dial does too.
        {
            let mut st = lock(&inner);
            while st.mode == Mode::Killed || st.mode == Mode::Partitioned {
                st = inner.1.wait(st).unwrap_or_else(PoisonError::into_inner);
            }
        }
        let (shard_r, shard_w) = match shard.connect() {
            Ok(d) => d,
            Err(_) => return,
        };
        let front_w: SharedWriter = Arc::new(Mutex::new(front_w));
        let shard_w: SharedWriter = Arc::new(Mutex::new(shard_w));
        {
            let mut st = lock(&inner);
            st.front_w = Some(Arc::clone(&front_w));
            st.shard_w = Some(Arc::clone(&shard_w));
            st.report.bridges += 1;
        }
        let to_shard = Arc::clone(&inner);
        let fw = Arc::clone(&front_w);
        let sc = Arc::clone(&sched);
        thread::spawn(move || pump_front_to_shard(front_r, shard_w, fw, to_shard, sc));
        let to_front = Arc::clone(&inner);
        thread::spawn(move || pump_shard_to_front(shard_r, front_w, to_front));
    }
}

/// Read one length-prefixed frame (prefix + body) whole; `None` on
/// EOF or a transport fault.  The proxy forwards opaque bytes — it
/// never decodes messages, only respects frame boundaries.
fn read_frame(r: &mut Box<dyn WireRead>) -> Option<Vec<u8>> {
    let mut frame = vec![0u8; 4];
    read_exact(r, &mut frame)?;
    let len = u32::from_le_bytes([frame[0], frame[1], frame[2], frame[3]]) as usize;
    if len > MAX_FRAME {
        // The peer itself lost framing; pass the prefix through and
        // let the receiver's reader report it.
        return Some(frame);
    }
    let mut body = vec![0u8; len];
    read_exact(r, &mut body)?;
    frame.extend_from_slice(&body);
    Some(frame)
}

fn read_exact(r: &mut Box<dyn WireRead>, buf: &mut [u8]) -> Option<()> {
    let mut at = 0;
    while at < buf.len() {
        match r.recv(&mut buf[at..]) {
            Ok(0) | Err(_) => return None,
            Ok(n) => at += n,
        }
    }
    Some(())
}

/// Front→shard pump: every frame advances the clock (firing due plan
/// entries fleet-wide) before its fate (forward/drop) is decided.
fn pump_front_to_shard(
    mut r: Box<dyn WireRead>,
    shard_w: SharedWriter,
    front_w: SharedWriter,
    inner: SwitchInner,
    sched: Arc<Scheduler>,
) {
    while let Some(frame) = read_frame(&mut r) {
        sched.advance();
        let forward = {
            let mut st = lock(&inner);
            st.report.ticks += 1;
            match st.mode {
                Mode::Killed => {
                    // Read from the pipe's backlog after the sever:
                    // the frame is gone either way — account it.
                    st.report.dropped += 1;
                    return;
                }
                Mode::Partitioned => {
                    st.report.dropped += 1;
                    false
                }
                Mode::Normal | Mode::Stalled => true,
            }
        };
        if forward {
            let mut w = shard_w.lock().unwrap_or_else(PoisonError::into_inner);
            if w.send(&frame).is_err() {
                // The real shard died underneath the proxy: sever the
                // front side so the loss is observable there too.
                front_w
                    .lock()
                    .unwrap_or_else(PoisonError::into_inner)
                    .shutdown();
                return;
            }
        }
    }
    // Front closed (shard loss handling or shutdown): the shard sees
    // FrontGone and loops back to accept.
    shard_w
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
        .shutdown();
}

/// Shard→front pump: stall buffers here, partitions drop here, and
/// heals flush strictly before anything newer is forwarded.
fn pump_shard_to_front(mut r: Box<dyn WireRead>, front_w: SharedWriter, inner: SwitchInner) {
    while let Some(frame) = read_frame(&mut r) {
        let forward = {
            let mut st = lock(&inner);
            match st.mode {
                Mode::Killed => {
                    st.report.dropped += 1;
                    return;
                }
                Mode::Stalled => {
                    st.stalled.push_back(frame.clone());
                    false
                }
                Mode::Partitioned => {
                    st.report.dropped += 1;
                    false
                }
                Mode::Normal => true,
            }
        };
        if forward {
            let mut w = front_w.lock().unwrap_or_else(PoisonError::into_inner);
            if w.send(&frame).is_err() {
                return;
            }
        }
    }
    // Shard closed: sever the front side so the front's reader
    // observes the loss promptly.
    front_w
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
        .shutdown();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_plans_are_reproducible_and_always_heal() {
        let a = ChaosPlan::seeded(42, 3, 50, 8);
        let b = ChaosPlan::seeded(42, 3, 50, 8);
        assert_eq!(a.faults().len(), b.faults().len());
        for (x, y) in a.faults().iter().zip(b.faults()) {
            assert_eq!((x.shard, x.tick), (y.shard, y.tick));
            assert_eq!(x.fault, y.fault);
        }
        let all = a.faults();
        assert!(
            all.windows(2).all(|w| w[0].tick <= w[1].tick),
            "globally tick-ordered"
        );
        // Episodes never overlap: each fault's heal lands before the
        // next fault fires, so capacity is always >= shards - 1.
        let mut active: Option<usize> = None;
        for f in all {
            match f.fault {
                Fault::Heal => {
                    assert_eq!(active, Some(f.shard), "heal matches the open fault");
                    active = None;
                }
                _ => {
                    assert_eq!(active, None, "no overlapping fault episodes");
                    active = Some(f.shard);
                }
            }
        }
        assert_eq!(active, None, "plan ends healed");
        assert_ne!(
            ChaosPlan::seeded(1, 3, 50, 8)
                .faults()
                .iter()
                .map(|f| (f.shard, f.tick))
                .collect::<Vec<_>>(),
            ChaosPlan::seeded(2, 3, 50, 8)
                .faults()
                .iter()
                .map(|f| (f.shard, f.tick))
                .collect::<Vec<_>>(),
            "different seeds, different plans"
        );
    }

    #[test]
    fn proxy_forwards_frames_and_counts_ticks() {
        let backend = LoopbackHub::new();
        let echo = backend.clone();
        thread::spawn(move || {
            // Minimal byte-echo shard: one connection, frame-agnostic.
            let (mut r, mut w) = echo.accept().expect("accept");
            let mut buf = [0u8; 256];
            loop {
                match r.recv(&mut buf) {
                    Ok(0) | Err(_) => return,
                    Ok(n) => {
                        if w.send(&buf[..n]).is_err() {
                            return;
                        }
                    }
                }
            }
        });
        let (hub, switch) = chaos_wrap(Arc::new(backend), Vec::new());
        let (mut r, mut w) = hub.connect().expect("dial proxy");
        // One well-formed 3-byte frame.
        w.send(&[3, 0, 0, 0, 9, 8, 7]).expect("send");
        let mut got = Vec::new();
        let mut buf = [0u8; 16];
        while got.len() < 7 {
            let n = r.recv(&mut buf).expect("echo back");
            assert!(n > 0, "echo closed early");
            got.extend_from_slice(&buf[..n]);
        }
        assert_eq!(got, vec![3, 0, 0, 0, 9, 8, 7]);
        let rep = switch.report();
        assert_eq!(rep.ticks, 1);
        assert_eq!(rep.bridges, 1);
        assert_eq!(rep.dropped, 0);
        switch.close();
    }

    #[test]
    fn partition_drops_and_kill_severs() {
        let backend = LoopbackHub::new();
        let sink = backend.clone();
        thread::spawn(move || {
            let (mut r, _w) = sink.accept().expect("accept");
            let mut buf = [0u8; 64];
            while matches!(r.recv(&mut buf), Ok(n) if n > 0) {}
        });
        let (hub, switch) = chaos_wrap(Arc::new(backend), Vec::new());
        let (mut r, mut w) = hub.connect().expect("dial proxy");
        switch.apply(Fault::Partition);
        w.send(&[1, 0, 0, 0, 5]).expect("write succeeds into grey hole");
        // Grey failure: the write went through, the frame vanished.
        // Spin until the pump has accounted it.
        while switch.report().dropped == 0 {
            thread::yield_now();
        }
        assert_eq!(switch.report().ticks, 1);
        switch.apply(Fault::Kill);
        let mut buf = [0u8; 8];
        assert_eq!(r.recv(&mut buf).expect("EOF after kill"), 0);
        switch.close();
    }

    #[test]
    fn fleet_clock_fires_one_shards_plan_from_anothers_traffic() {
        // Shard 0 is killed by its own first frame; its heal at tick 4
        // can only be carried by shard 1's traffic.
        let mk_sink = || {
            let backend = LoopbackHub::new();
            let sink = backend.clone();
            thread::spawn(move || loop {
                let Ok((mut r, _w)) = sink.accept() else { return };
                thread::spawn(move || {
                    let mut buf = [0u8; 64];
                    while matches!(r.recv(&mut buf), Ok(n) if n > 0) {}
                });
            });
            backend
        };
        let plan = ChaosPlan::new(vec![
            PlannedFault { shard: 0, tick: 1, fault: Fault::Kill },
            PlannedFault { shard: 0, tick: 4, fault: Fault::Heal },
        ]);
        let (hubs, fleet) = ChaosFleet::wrap(
            vec![Arc::new(mk_sink()) as Arc<dyn Transport>, Arc::new(mk_sink())],
            &plan,
        );
        let (_r0, mut w0) = hubs[0].connect().expect("dial shard 0 proxy");
        let (_r1, mut w1) = hubs[1].connect().expect("dial shard 1 proxy");
        w0.send(&[1, 0, 0, 0, 1]).expect("tick 1 kills shard 0");
        while fleet.ticks() < 1 {
            thread::yield_now();
        }
        // Ticks 2..4 ride shard 1; the last one heals shard 0.
        for _ in 0..3 {
            w1.send(&[1, 0, 0, 0, 2]).expect("survivor traffic");
        }
        while fleet.unfired() > 0 {
            thread::yield_now();
        }
        // Healed: a fresh dial to shard 0 bridges again.
        let (_r, mut w) = hubs[0].connect().expect("re-dial shard 0");
        w.send(&[1, 0, 0, 0, 3]).expect("post-heal frame");
        while fleet.reports()[0].bridges < 2 {
            thread::yield_now();
        }
        assert_eq!(fleet.reports()[0].bridges, 2, "shard 0 re-bridged after heal");
        fleet.close();
    }
}
