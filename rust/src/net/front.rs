//! The scale-out front-end: admission control, session affinity, and
//! warm cross-shard migration over N backend shards (DESIGN.md §14).
//!
//! One router thread owns every connection writer and the session
//! table; per-connection and per-shard reader threads feed it a single
//! event queue, so all protocol decisions are serialized and the data
//! path needs no locks.  Each session is pinned to one shard
//! (affinity); the front keeps, per session, the last `warmup` *acked*
//! frames plus everything sent-but-unacked, which is exactly the state
//! needed to re-create the session on another shard by §9 replay:
//!
//! * **planned migration** ([`FrontHandle::migrate`]) holds new input
//!   until the shard acks everything outstanding, then moves with
//!   `Migrate { t: acked, history }` — zero frames dropped, outputs
//!   bit-identical to never having moved;
//! * **shard loss** re-homes every orphaned session the same way and
//!   then re-sends the unacked tail, because the dead shard will never
//!   emit those outputs.
//!
//! Faults on one connection — truncated frames, version skew, a
//! mid-stream disconnect — answer with one typed `Err` (or just drop
//! that connection) and never touch sibling sessions.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::{self, JoinHandle};

use anyhow::{anyhow, bail, Context, Result};

use super::transport::{Listener, Transport, WireWrite};
use super::wire::{role, write_msg, ErrCode, FrameReader, Msg, WireError, DRAIN_ALL, WIRE_VERSION};
use crate::obs::{Counter, ObsHandle, SpanKind, Telemetry, TraceCtx, TraceSampler};

/// One backend shard as the front-end sees it: a name for logs and a
/// way to reach it.
pub struct ShardLink {
    /// Human-readable shard name (logs and errors only).
    pub name: String,
    /// How to reach the shard.
    pub transport: Box<dyn Transport>,
}

/// Front-end admission policy.
#[derive(Debug, Clone, Copy)]
pub struct FrontPolicy {
    /// Sessions admitted across the whole fleet; the next new session
    /// is refused with [`ErrCode::AdmissionDenied`].
    pub max_sessions: usize,
    /// Trace every `n`th forwarded frame end to end (DESIGN.md §15);
    /// 0 — the default — disables tracing entirely and keeps wire
    /// encodings byte-identical to untraced `soi.wire.v1`.
    pub trace_sample_n: u64,
}

impl Default for FrontPolicy {
    fn default() -> Self {
        FrontPolicy {
            max_sessions: 64,
            trace_sample_n: 0,
        }
    }
}

/// What the front-end counted over its lifetime.
#[derive(Debug, Clone, Copy, Default)]
pub struct FrontReport {
    /// Client connections accepted.
    pub conns: u64,
    /// Sessions admitted.
    pub admitted: u64,
    /// Sessions refused by [`FrontPolicy::max_sessions`].
    pub denied: u64,
    /// Client frames forwarded to shards.
    pub frames_in: u64,
    /// Output frames forwarded back to clients.
    pub frames_out: u64,
    /// Warm migrations completed (planned and crash-driven).
    pub migrations: u64,
    /// Shard connections lost.
    pub shard_losses: u64,
    /// Typed wire faults observed on either side.
    pub wire_errs: u64,
}

/// Everything the router can be woken by.
enum FrontEvent {
    /// Acceptor registered a new client connection's write half.
    NewConn(u64, Box<dyn WireWrite>),
    /// A client connection's reader produced a message (or died).
    FromClient(u64, Result<Option<Msg>, WireError>),
    /// A shard connection's reader produced a message (or died).
    FromShard(usize, Result<Option<Msg>, WireError>),
    /// Operator command: move `session` to shard `to`.
    Migrate { session: u64, to: usize },
    /// Operator command: move one session off shard `from` onto `to`
    /// (the cluster controller's actuator — it names shards, not
    /// sessions).
    Rebalance { from: usize, to: usize },
    /// Shut down: drain shards, close connections, report.
    Stop,
}

struct ShardConn {
    name: String,
    writer: Box<dyn WireWrite>,
    /// Cleared on the first failed write; its reader soon reports too.
    reachable: bool,
    /// Set once [`lose_shard`] has re-homed the orphans, whichever of
    /// the write or read side noticed the death first.
    lost: bool,
}

struct ConnState {
    writer: Box<dyn WireWrite>,
    greeted: bool,
}

struct SessionState {
    conn: u64,
    shard: usize,
    /// Next input seq expected from the client.
    next_seq: u64,
    /// Frames sent to the shard (== seq of the next frame to send).
    sent: u64,
    /// Frames whose output came back.
    acked: u64,
    /// Last `warmup` acked frames — the §9 replay window.
    history: VecDeque<Vec<f32>>,
    /// Sent-but-unacked frames, oldest first: `(seq, last, samples)`.
    inflight: VecDeque<(u64, bool, Vec<f32>)>,
    /// Frames held back while a planned migration waits for the
    /// inflight window to drain.
    held: VecDeque<(u64, bool, Vec<f32>)>,
    /// Planned migration target, if one is pending.
    migrating_to: Option<usize>,
}

/// A running front-end.  Dropping the handle abandons the router;
/// call [`FrontHandle::stop`] for a clean shutdown and its report.
pub struct FrontHandle {
    tx: Sender<FrontEvent>,
    router: Option<JoinHandle<FrontReport>>,
    listener: Arc<dyn Listener>,
}

impl FrontHandle {
    /// Nominate a planned warm migration of `session` onto `to_shard`.
    /// Executed asynchronously; invalid targets are ignored.
    pub fn migrate(&self, session: u64, to_shard: usize) -> Result<()> {
        self.tx
            .send(FrontEvent::Migrate {
                session,
                to: to_shard,
            })
            .map_err(|_| anyhow!("front router is gone"))
    }

    /// Execute a cluster-controller decision: move one session off
    /// shard `from` onto shard `to`.
    pub fn rebalance(&self, from: usize, to: usize) -> Result<()> {
        self.tx
            .send(FrontEvent::Rebalance { from, to })
            .map_err(|_| anyhow!("front router is gone"))
    }

    /// Stop accepting, drain every shard, and return the report.
    pub fn stop(mut self) -> Result<FrontReport> {
        let _ = self.tx.send(FrontEvent::Stop);
        self.listener.close();
        let handle = self.router.take().expect("router joined once");
        handle.join().map_err(|_| anyhow!("front router panicked"))
    }
}

/// Connect to every shard, verify they serve the same model shape,
/// and start the acceptor + router.  Fails fast if any shard is
/// unreachable, speaks another wire version, or disagrees on
/// `(feat, period, warmup)`.
pub fn spawn_front(
    listener: Box<dyn Listener>,
    shards: Vec<ShardLink>,
    policy: FrontPolicy,
) -> Result<FrontHandle> {
    spawn_front_with(listener, shards, policy, None)
}

/// [`spawn_front`] with telemetry: the router records its wire
/// counters, admission spans, and migration spans through the root's
/// shared handle, so a front-end exports the same `soi.obs.v1` feed a
/// shard does and `soi aggregate-feeds` can merge both sides.
pub fn spawn_front_with(
    listener: Box<dyn Listener>,
    shards: Vec<ShardLink>,
    policy: FrontPolicy,
    telemetry: Option<Arc<Telemetry>>,
) -> Result<FrontHandle> {
    if shards.is_empty() {
        bail!("front needs at least one shard");
    }
    let (tx, rx) = channel::<FrontEvent>();

    // Handshake each shard synchronously: we speak first.
    let mut shard_conns = Vec::with_capacity(shards.len());
    let mut shape: Option<(u32, u32, u32)> = None;
    for (idx, link) in shards.into_iter().enumerate() {
        let (r, mut w) = link
            .transport
            .connect()
            .map_err(|e| anyhow!("shard '{}' unreachable: {e}", link.name))?;
        let hello = Msg::Hello {
            version: WIRE_VERSION,
            role: role::FRONT,
            feat: 0,
            period: 0,
            warmup: 0,
        };
        write_msg(&mut w, &hello).map_err(|e| anyhow!("shard '{}': {e}", link.name))?;
        let mut reader = FrameReader::new(r);
        let ack = reader
            .next_msg()
            .map_err(|e| anyhow!("shard '{}' handshake: {e}", link.name))?
            .with_context(|| format!("shard '{}' closed during handshake", link.name))?;
        let Msg::Hello {
            role: r_role,
            feat,
            period,
            warmup,
            ..
        } = ack
        else {
            bail!("shard '{}' greeted with {}", link.name, ack.kind());
        };
        if r_role != role::SHARD {
            bail!("shard '{}' claims role {r_role}, expected shard", link.name);
        }
        match shape {
            None => shape = Some((feat, period, warmup)),
            Some(s) if s != (feat, period, warmup) => bail!(
                "shard '{}' serves feat/period/warmup {:?}, fleet serves {:?}",
                link.name,
                (feat, period, warmup),
                s
            ),
            Some(_) => {}
        }
        // Reader thread keeps the (already buffered) FrameReader.
        let shard_tx = tx.clone();
        thread::spawn(move || {
            pump_reader(reader, move |item| {
                let fatal = is_fatal(&item);
                shard_tx.send(FrontEvent::FromShard(idx, item)).is_err() || fatal
            })
        });
        shard_conns.push(ShardConn {
            name: link.name,
            writer: w,
            reachable: true,
            lost: false,
        });
    }
    let (feat, period, warmup) = shape.expect("nonempty fleet");

    // Acceptor: register the write half, then stream reads.
    let listener: Arc<dyn Listener> = Arc::from(listener);
    let accept_tx = tx.clone();
    let accept_listener = listener.clone();
    thread::spawn(move || {
        let mut next_conn = 0u64;
        loop {
            let (r, w) = match accept_listener.accept() {
                Ok(d) => d,
                Err(_) => return,
            };
            let id = next_conn;
            next_conn += 1;
            if accept_tx.send(FrontEvent::NewConn(id, w)).is_err() {
                return;
            }
            let conn_tx = accept_tx.clone();
            thread::spawn(move || {
                pump_reader(FrameReader::new(r), move |item| {
                    let fatal = is_fatal(&item);
                    conn_tx.send(FrontEvent::FromClient(id, item)).is_err() || fatal
                })
            });
        }
    });

    let fo = FrontObs {
        obs: telemetry.map(|t| t.shared()),
        sampler: TraceSampler::new(policy.trace_sample_n),
    };
    let router =
        thread::spawn(move || run_router(rx, shard_conns, policy, fo, feat, period, warmup));
    Ok(FrontHandle {
        tx,
        router: Some(router),
        listener,
    })
}

/// Drive a [`FrameReader`] until `deliver` says stop (it returns true
/// on fatal items or when the router is gone).
fn pump_reader<R: super::transport::WireRead + 'static>(
    mut reader: FrameReader<R>,
    mut deliver: impl FnMut(Result<Option<Msg>, WireError>) -> bool,
) {
    loop {
        if deliver(reader.next_msg()) {
            return;
        }
    }
}

/// A reader item after which the byte stream cannot continue.
fn is_fatal(item: &Result<Option<Msg>, WireError>) -> bool {
    match item {
        Ok(None) => true,
        Ok(Some(_)) => false,
        Err(e) => !matches!(
            e,
            WireError::UnknownTag { .. }
                | WireError::Malformed { .. }
                | WireError::VersionSkew { .. }
        ),
    }
}

/// The router's observability state: one recording handle (when
/// telemetry is on) plus the head-based trace sampler (DESIGN.md §15).
/// Owned by the router thread; nothing here is shared or locked beyond
/// the handle's own per-record mutex.
struct FrontObs {
    obs: Option<ObsHandle>,
    sampler: TraceSampler,
}

impl FrontObs {
    fn count(&self, c: Counter, n: u64) {
        if let Some(h) = &self.obs {
            h.count(c, n);
        }
    }

    /// Head sampling: every `n`th forwarded frame opens a trace.  The
    /// root `front_admit` span is recorded here; the returned context
    /// rides the `Frame` to the owning shard.
    fn sample_frame(&mut self, session: u64, seq: u64, shard: usize) -> Option<TraceCtx> {
        let id = self.sampler.sample()?;
        if let Some(h) = &self.obs {
            h.span(id, SpanKind::FrontAdmit, 0, session, seq, shard as u64);
        }
        Some(TraceCtx::root(id, SpanKind::FrontAdmit))
    }

    /// Migrations are rare and exactly what an operator wants linked:
    /// when sampling is on at all, every migration opens a trace.
    fn trace_migration(&mut self, session: u64, from: usize, to: usize) -> Option<TraceCtx> {
        if !self.sampler.enabled() {
            return None;
        }
        let id = self.sampler.force();
        if let Some(h) = &self.obs {
            h.span(
                id,
                SpanKind::MigrateFront,
                0,
                session,
                from as u64,
                to as u64,
            );
        }
        Some(TraceCtx::root(id, SpanKind::MigrateFront))
    }
}

fn send_to_shard(shards: &mut [ShardConn], idx: usize, msg: &Msg, fo: &FrontObs) -> bool {
    let s = &mut shards[idx];
    if !s.reachable {
        return false;
    }
    match write_msg(s.writer.as_mut(), msg) {
        Ok(n) => {
            if let Some(h) = &fo.obs {
                h.with(|o| {
                    o.count(Counter::WireTxMsgs, 1);
                    o.count(Counter::WireTxBytes, n as u64);
                });
            }
            true
        }
        Err(_) => {
            s.reachable = false;
            false
        }
    }
}

fn send_to_conn(conns: &mut HashMap<u64, ConnState>, id: u64, msg: &Msg, fo: &FrontObs) {
    // Typed errors sent to a client count under the total and their
    // own per-code counter (DESIGN.md appendix A, additive change).
    if let (Msg::Err { code, .. }, Some(h)) = (msg, &fo.obs) {
        let code = *code;
        h.with(|o| {
            o.count(Counter::WireErrs, 1);
            o.count(code.counter(), 1);
        });
    }
    if let Some(c) = conns.get_mut(&id) {
        // A failed client write surfaces as EOF on its reader; nothing
        // more to do here.
        if let Ok(n) = write_msg(c.writer.as_mut(), msg) {
            if let Some(h) = &fo.obs {
                h.with(|o| {
                    o.count(Counter::WireTxMsgs, 1);
                    o.count(Counter::WireTxBytes, n as u64);
                });
            }
        }
    }
}

fn load_of(sessions: &HashMap<u64, SessionState>, shard: usize) -> usize {
    sessions.values().filter(|s| s.shard == shard).count()
}

/// Least-loaded reachable shard, optionally excluding one.
fn pick_shard(
    shards: &[ShardConn],
    sessions: &HashMap<u64, SessionState>,
    exclude: Option<usize>,
) -> Option<usize> {
    shards
        .iter()
        .enumerate()
        .filter(|(i, s)| s.reachable && Some(*i) != exclude)
        .min_by_key(|(i, _)| load_of(sessions, *i))
        .map(|(i, _)| i)
}

#[allow(clippy::too_many_arguments)]
fn run_router(
    rx: Receiver<FrontEvent>,
    mut shards: Vec<ShardConn>,
    policy: FrontPolicy,
    mut fo: FrontObs,
    feat: u32,
    period: u32,
    warmup: u32,
) -> FrontReport {
    let mut conns: HashMap<u64, ConnState> = HashMap::new();
    let mut sessions: HashMap<u64, SessionState> = HashMap::new();
    let mut report = FrontReport::default();

    for ev in rx {
        match ev {
            FrontEvent::NewConn(id, writer) => {
                report.conns += 1;
                conns.insert(
                    id,
                    ConnState {
                        writer,
                        greeted: false,
                    },
                );
            }
            FrontEvent::FromClient(conn, item) => match item {
                Ok(Some(msg)) => {
                    fo.count(Counter::WireRxMsgs, 1);
                    handle_client_msg(
                        conn,
                        msg,
                        &mut conns,
                        &mut sessions,
                        &mut shards,
                        &policy,
                        &mut fo,
                        feat,
                        period,
                        warmup,
                        &mut report,
                    );
                }
                Ok(None) => {
                    drop_conn(conn, &mut conns, &mut sessions, &mut shards, &fo);
                }
                Err(e) => {
                    report.wire_errs += 1;
                    if is_fatal(&Err(e.clone())) {
                        // No Err goes back out, so the fault is counted
                        // here rather than by send_to_conn.
                        fo.count(Counter::WireErrs, 1);
                        drop_conn(conn, &mut conns, &mut sessions, &mut shards, &fo);
                    } else {
                        let code = if matches!(e, WireError::VersionSkew { .. }) {
                            ErrCode::VersionSkew
                        } else {
                            ErrCode::BadFrame
                        };
                        send_to_conn(
                            &mut conns,
                            conn,
                            &Msg::Err {
                                code,
                                session: 0,
                                detail: e.to_string(),
                            },
                            &fo,
                        );
                    }
                }
            },
            FrontEvent::FromShard(idx, item) => match item {
                Ok(Some(msg)) => {
                    fo.count(Counter::WireRxMsgs, 1);
                    handle_shard_msg(
                        idx,
                        msg,
                        &mut conns,
                        &mut sessions,
                        &mut shards,
                        &mut fo,
                        feat,
                        warmup,
                        &mut report,
                    );
                }
                Ok(None) => {
                    lose_shard(
                        idx,
                        &mut conns,
                        &mut sessions,
                        &mut shards,
                        &mut fo,
                        feat,
                        &mut report,
                    );
                }
                Err(e) => {
                    report.wire_errs += 1;
                    fo.count(Counter::WireErrs, 1);
                    if is_fatal(&Err(e)) {
                        lose_shard(
                            idx,
                            &mut conns,
                            &mut sessions,
                            &mut shards,
                            &mut fo,
                            feat,
                            &mut report,
                        );
                    }
                }
            },
            FrontEvent::Migrate { session, to } => {
                start_migration(
                    session,
                    to,
                    &mut conns,
                    &mut sessions,
                    &mut shards,
                    &mut fo,
                    feat,
                    &mut report,
                );
            }
            FrontEvent::Rebalance { from, to } => {
                // Prefer a quiet session (empty inflight) so the move
                // completes immediately.
                let pick = sessions
                    .iter()
                    .filter(|(_, s)| s.shard == from && s.migrating_to.is_none())
                    .min_by_key(|(_, s)| s.inflight.len())
                    .map(|(id, _)| *id);
                if let Some(sid) = pick {
                    start_migration(
                        sid,
                        to,
                        &mut conns,
                        &mut sessions,
                        &mut shards,
                        &mut fo,
                        feat,
                        &mut report,
                    );
                }
            }
            FrontEvent::Stop => break,
        }
    }

    for idx in 0..shards.len() {
        send_to_shard(&mut shards, idx, &Msg::Drain { session: DRAIN_ALL }, &fo);
        shards[idx].writer.shutdown();
    }
    for c in conns.values_mut() {
        c.writer.shutdown();
    }
    report
}

#[allow(clippy::too_many_arguments)]
fn handle_client_msg(
    conn: u64,
    msg: Msg,
    conns: &mut HashMap<u64, ConnState>,
    sessions: &mut HashMap<u64, SessionState>,
    shards: &mut [ShardConn],
    policy: &FrontPolicy,
    fo: &mut FrontObs,
    feat: u32,
    period: u32,
    warmup: u32,
    report: &mut FrontReport,
) {
    let greeted = conns.get(&conn).map(|c| c.greeted).unwrap_or(false);
    match msg {
        Msg::Hello { role: r, .. } => {
            if greeted || r != role::CLIENT {
                report.wire_errs += 1;
                send_to_conn(
                    conns,
                    conn,
                    &Msg::Err {
                        code: ErrCode::Protocol,
                        session: 0,
                        detail: "unexpected hello".into(),
                    },
                    fo,
                );
                return;
            }
            if let Some(c) = conns.get_mut(&conn) {
                c.greeted = true;
            }
            send_to_conn(
                conns,
                conn,
                &Msg::Hello {
                    version: WIRE_VERSION,
                    role: role::FRONT,
                    feat,
                    period,
                    warmup,
                },
                fo,
            );
        }
        Msg::Frame {
            session,
            seq,
            last,
            samples,
            ..
        } => {
            if !greeted {
                report.wire_errs += 1;
                send_to_conn(
                    conns,
                    conn,
                    &Msg::Err {
                        code: ErrCode::Protocol,
                        session,
                        detail: "frame before hello".into(),
                    },
                    fo,
                );
                return;
            }
            if samples.len() != feat as usize {
                report.wire_errs += 1;
                let detail = format!("frame has {} samples, feat is {feat}", samples.len());
                send_to_conn(
                    conns,
                    conn,
                    &Msg::Err {
                        code: ErrCode::BadFrame,
                        session,
                        detail,
                    },
                    fo,
                );
                return;
            }
            if !sessions.contains_key(&session) {
                // Admission: refuse before creating anything.
                if seq != 0 {
                    report.wire_errs += 1;
                    let detail = format!("unknown session starts at seq {seq}, expected 0");
                    send_to_conn(
                        conns,
                        conn,
                        &Msg::Err {
                            code: ErrCode::BadFrame,
                            session,
                            detail,
                        },
                        fo,
                    );
                    return;
                }
                if sessions.len() >= policy.max_sessions {
                    report.denied += 1;
                    report.wire_errs += 1;
                    let detail = format!("fleet serves {} sessions", policy.max_sessions);
                    send_to_conn(
                        conns,
                        conn,
                        &Msg::Err {
                            code: ErrCode::AdmissionDenied,
                            session,
                            detail,
                        },
                        fo,
                    );
                    return;
                }
                let Some(target) = pick_shard(shards, sessions, None) else {
                    report.wire_errs += 1;
                    send_to_conn(
                        conns,
                        conn,
                        &Msg::Err {
                            code: ErrCode::ShardLost,
                            session,
                            detail: "no reachable shard".into(),
                        },
                        fo,
                    );
                    return;
                };
                report.admitted += 1;
                sessions.insert(
                    session,
                    SessionState {
                        conn,
                        shard: target,
                        next_seq: 0,
                        sent: 0,
                        acked: 0,
                        history: VecDeque::new(),
                        inflight: VecDeque::new(),
                        held: VecDeque::new(),
                        migrating_to: None,
                    },
                );
            }
            let sess = sessions.get_mut(&session).expect("just ensured");
            if sess.conn != conn {
                report.wire_errs += 1;
                send_to_conn(
                    conns,
                    conn,
                    &Msg::Err {
                        code: ErrCode::Protocol,
                        session,
                        detail: "session owned by another connection".into(),
                    },
                    fo,
                );
                return;
            }
            if seq != sess.next_seq {
                report.wire_errs += 1;
                let detail = format!("frame seq {seq}, expected {}", sess.next_seq);
                send_to_conn(
                    conns,
                    conn,
                    &Msg::Err {
                        code: ErrCode::BadFrame,
                        session,
                        detail,
                    },
                    fo,
                );
                return;
            }
            sess.next_seq += 1;
            report.frames_in += 1;
            if sess.migrating_to.is_some() {
                sess.held.push_back((seq, last, samples));
                return;
            }
            let shard = sess.shard;
            sess.inflight.push_back((seq, last, samples.clone()));
            sess.sent += 1;
            // Only directly-forwarded frames are sampled; held frames
            // flushed after a migration replay ride untraced (the
            // migration itself carries its own forced trace).
            let frame = Msg::Frame {
                session,
                seq,
                last,
                samples,
                trace: fo.sample_frame(session, seq, shard),
            };
            if !send_to_shard(shards, shard, &frame, fo) {
                lose_shard(shard, conns, sessions, shards, fo, feat, report);
            }
        }
        Msg::Drain { session } => {
            if session == DRAIN_ALL {
                let mine: Vec<u64> = sessions
                    .iter()
                    .filter(|(_, s)| s.conn == conn)
                    .map(|(id, _)| *id)
                    .collect();
                for sid in mine {
                    retire_session(sid, sessions, shards, fo);
                }
                return;
            }
            if sessions.get(&session).map(|s| s.conn) == Some(conn) {
                retire_session(session, sessions, shards, fo);
            }
        }
        Msg::Migrate { .. } | Msg::FrameOut { .. } | Msg::Err { .. } => {
            report.wire_errs += 1;
            send_to_conn(
                conns,
                conn,
                &Msg::Err {
                    code: ErrCode::Protocol,
                    session: 0,
                    detail: "unexpected message".into(),
                },
                fo,
            );
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn handle_shard_msg(
    idx: usize,
    msg: Msg,
    conns: &mut HashMap<u64, ConnState>,
    sessions: &mut HashMap<u64, SessionState>,
    shards: &mut [ShardConn],
    fo: &mut FrontObs,
    feat: u32,
    warmup: u32,
    report: &mut FrontReport,
) {
    match msg {
        Msg::FrameOut {
            session,
            seq,
            samples,
            trace,
        } => {
            let Some(sess) = sessions.get_mut(&session) else {
                return; // retired while the output was in flight
            };
            if sess.shard != idx {
                return; // stale output from the pre-migration shard
            }
            let Some((fseq, last, frame)) = sess.inflight.pop_front() else {
                report.wire_errs += 1;
                fo.count(Counter::WireErrs, 1);
                return;
            };
            if fseq != seq {
                // The shard's absolute counter disagrees with ours —
                // a protocol bug, not a client fault.  Drop the pair.
                report.wire_errs += 1;
                fo.count(Counter::WireErrs, 1);
                return;
            }
            sess.acked += 1;
            sess.history.push_back(frame);
            while sess.history.len() > warmup as usize {
                sess.history.pop_front();
            }
            let conn = sess.conn;
            let finished = last;
            let move_now = sess.migrating_to.is_some() && sess.inflight.is_empty();
            report.frames_out += 1;
            // Close the loop on a traced frame: record the reply hop
            // and echo the extended context to the client.
            let reply_trace = trace.map(|ctx| {
                if let Some(h) = &fo.obs {
                    h.span(ctx.trace_id, SpanKind::FrontReply, ctx.kind, session, seq, 0);
                }
                ctx.child(SpanKind::FrontReply)
            });
            send_to_conn(
                conns,
                conn,
                &Msg::FrameOut {
                    session,
                    seq,
                    samples,
                    trace: reply_trace,
                },
                fo,
            );
            if finished {
                sessions.remove(&session);
                return;
            }
            if move_now {
                complete_migration(session, conns, sessions, shards, fo, feat, report);
            }
        }
        Msg::Err {
            code,
            session,
            detail,
        } => {
            // Observed on receipt; forwarding it below counts the send
            // (total and per-code) in send_to_conn.
            report.wire_errs += 1;
            fo.count(Counter::WireErrs, 1);
            if session != 0 {
                if let Some(sess) = sessions.get(&session) {
                    let conn = sess.conn;
                    send_to_conn(
                        conns,
                        conn,
                        &Msg::Err {
                            code,
                            session,
                            detail,
                        },
                        fo,
                    );
                }
            }
        }
        // Shards never originate anything else after the handshake.
        Msg::Hello { .. } | Msg::Frame { .. } | Msg::Migrate { .. } | Msg::Drain { .. } => {
            report.wire_errs += 1;
            fo.count(Counter::WireErrs, 1);
        }
    }
}

/// Begin a planned migration; completes immediately when nothing is
/// in flight, otherwise when the last outstanding output arrives.
#[allow(clippy::too_many_arguments)]
fn start_migration(
    session: u64,
    to: usize,
    conns: &mut HashMap<u64, ConnState>,
    sessions: &mut HashMap<u64, SessionState>,
    shards: &mut [ShardConn],
    fo: &mut FrontObs,
    feat: u32,
    report: &mut FrontReport,
) {
    let Some(sess) = sessions.get_mut(&session) else {
        return;
    };
    if to >= shards.len() || !shards[to].reachable || to == sess.shard {
        return;
    }
    sess.migrating_to = Some(to);
    if sess.inflight.is_empty() {
        complete_migration(session, conns, sessions, shards, fo, feat, report);
    }
}

/// The inflight window is empty: retire the session on the old shard,
/// re-create it on the target by §9 replay, and flush held frames.
fn complete_migration(
    session: u64,
    conns: &mut HashMap<u64, ConnState>,
    sessions: &mut HashMap<u64, SessionState>,
    shards: &mut [ShardConn],
    fo: &mut FrontObs,
    feat: u32,
    report: &mut FrontReport,
) {
    let Some(sess) = sessions.get_mut(&session) else {
        return;
    };
    let Some(to) = sess.migrating_to.take() else {
        return;
    };
    let from = sess.shard;
    debug_assert!(sess.inflight.is_empty());
    send_to_shard(shards, from, &Msg::Drain { session }, fo);
    let hist: Vec<Vec<f32>> = sess.history.iter().cloned().collect();
    let t = sess.acked;
    let migrate = Msg::Migrate {
        session,
        t,
        feat,
        history: hist,
        trace: fo.trace_migration(session, from, to),
    };
    let sess = sessions.get_mut(&session).expect("still live");
    if !send_to_shard(shards, to, &migrate, fo) {
        // Target died at handoff.  The old shard already dropped the
        // session, so this is now a crash re-home, not a cancel.
        sess.shard = to;
        rehome_session(session, conns, sessions, shards, fo, feat, report);
        return;
    }
    sess.shard = to;
    report.migrations += 1;
    let held: Vec<(u64, bool, Vec<f32>)> = sess.held.drain(..).collect();
    for (seq, last, samples) in held {
        let sess = sessions.get_mut(&session).expect("still live");
        sess.inflight.push_back((seq, last, samples.clone()));
        sess.sent += 1;
        let frame = Msg::Frame {
            session,
            seq,
            last,
            samples,
            trace: None,
        };
        if !send_to_shard(shards, to, &frame, fo) {
            // The frame is recorded inflight; losing the shard now
            // re-homes the session and re-sends the tail.
            lose_shard(to, conns, sessions, shards, fo, feat, report);
            return;
        }
    }
}

/// A shard died: mark it, cancel migrations that were *targeting* it,
/// and re-home every session *homed* on it by §9 replay — including a
/// re-send of the unacked tail, whose outputs the dead shard will
/// never deliver.
fn lose_shard(
    idx: usize,
    conns: &mut HashMap<u64, ConnState>,
    sessions: &mut HashMap<u64, SessionState>,
    shards: &mut [ShardConn],
    fo: &mut FrontObs,
    feat: u32,
    report: &mut FrontReport,
) {
    if shards[idx].lost {
        return; // the other half (reader/writer) noticed first
    }
    shards[idx].lost = true;
    shards[idx].reachable = false;
    shards[idx].writer.shutdown();
    report.shard_losses += 1;
    let nominated: Vec<u64> = sessions
        .iter()
        .filter(|(_, s)| s.shard != idx && s.migrating_to == Some(idx))
        .map(|(id, _)| *id)
        .collect();
    for sid in nominated {
        cancel_migration(sid, conns, sessions, shards, fo, feat, report);
    }
    let orphans: Vec<u64> = sessions
        .iter()
        .filter(|(_, s)| s.shard == idx)
        .map(|(id, _)| *id)
        .collect();
    for sid in orphans {
        rehome_session(sid, conns, sessions, shards, fo, feat, report);
    }
}

/// A planned migration's target died before the handoff: forget the
/// nomination and flush held frames to the still-live current shard.
fn cancel_migration(
    session: u64,
    conns: &mut HashMap<u64, ConnState>,
    sessions: &mut HashMap<u64, SessionState>,
    shards: &mut [ShardConn],
    fo: &mut FrontObs,
    feat: u32,
    report: &mut FrontReport,
) {
    let Some(sess) = sessions.get_mut(&session) else {
        return;
    };
    sess.migrating_to = None;
    let shard = sess.shard;
    let held: Vec<(u64, bool, Vec<f32>)> = sess.held.drain(..).collect();
    for (seq, last, samples) in held {
        let sess = sessions.get_mut(&session).expect("still live");
        sess.inflight.push_back((seq, last, samples.clone()));
        sess.sent += 1;
        let frame = Msg::Frame {
            session,
            seq,
            last,
            samples,
            trace: None,
        };
        if !send_to_shard(shards, shard, &frame, fo) {
            lose_shard(shard, conns, sessions, shards, fo, feat, report);
            return;
        }
    }
}

fn rehome_session(
    session: u64,
    conns: &mut HashMap<u64, ConnState>,
    sessions: &mut HashMap<u64, SessionState>,
    shards: &mut [ShardConn],
    fo: &mut FrontObs,
    feat: u32,
    report: &mut FrontReport,
) {
    loop {
        let Some(sess) = sessions.get_mut(&session) else {
            return;
        };
        sess.migrating_to = None;
        let Some(target) = pick_shard(shards, sessions, Some(sessions[&session].shard)) else {
            let conn = sessions[&session].conn;
            sessions.remove(&session);
            send_to_conn(
                conns,
                conn,
                &Msg::Err {
                    code: ErrCode::ShardLost,
                    session,
                    detail: "no reachable shard to resume on".into(),
                },
                fo,
            );
            return;
        };
        let sess = sessions.get_mut(&session).expect("still live");
        let from = sess.shard;
        let migrate = Msg::Migrate {
            session,
            t: sess.acked,
            feat,
            history: sess.history.iter().cloned().collect(),
            trace: fo.trace_migration(session, from, target),
        };
        if !send_to_shard(shards, target, &migrate, fo) {
            continue; // target just died too; try the next candidate
        }
        sess.shard = target;
        // Re-send everything the dead shard never acked, then held.
        let resend: Vec<(u64, bool, Vec<f32>)> = sess
            .inflight
            .iter()
            .cloned()
            .chain(sess.held.drain(..))
            .collect();
        sess.inflight.clear();
        let mut ok = true;
        for (seq, last, samples) in resend {
            let sess = sessions.get_mut(&session).expect("still live");
            sess.inflight.push_back((seq, last, samples.clone()));
            let frame = Msg::Frame {
                session,
                seq,
                last,
                samples,
                trace: None,
            };
            if !send_to_shard(shards, target, &frame, fo) {
                ok = false;
                break;
            }
        }
        let sess = sessions.get_mut(&session).expect("still live");
        sess.sent = sess.acked + sess.inflight.len() as u64;
        if ok {
            report.migrations += 1;
            return;
        }
        // Target died mid-replay: loop and pick another.
    }
}

/// Forget a session and tell its shard to do the same.
fn retire_session(
    session: u64,
    sessions: &mut HashMap<u64, SessionState>,
    shards: &mut [ShardConn],
    fo: &FrontObs,
) {
    if let Some(sess) = sessions.remove(&session) {
        send_to_shard(shards, sess.shard, &Msg::Drain { session }, fo);
    }
}

/// Drop a client connection and retire every session it owned.
fn drop_conn(
    conn: u64,
    conns: &mut HashMap<u64, ConnState>,
    sessions: &mut HashMap<u64, SessionState>,
    shards: &mut [ShardConn],
    fo: &FrontObs,
) {
    if let Some(mut c) = conns.remove(&conn) {
        c.writer.shutdown();
    }
    let mine: Vec<u64> = sessions
        .iter()
        .filter(|(_, s)| s.conn == conn)
        .map(|(id, _)| *id)
        .collect();
    for sid in mine {
        retire_session(sid, sessions, shards, fo);
    }
}
