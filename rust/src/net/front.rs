//! The scale-out front-end: admission control, session affinity, and
//! warm cross-shard migration over N backend shards (DESIGN.md §14).
//!
//! One router thread owns every connection writer and the session
//! table; per-connection and per-shard reader threads feed it a single
//! event queue, so all protocol decisions are serialized and the data
//! path needs no locks.  Each session is pinned to one shard
//! (affinity); the front keeps, per session, the last `warmup` *acked*
//! frames plus everything sent-but-unacked, which is exactly the state
//! needed to re-create the session on another shard by §9 replay:
//!
//! * **planned migration** ([`FrontHandle::migrate`]) holds new input
//!   until the shard acks everything outstanding, then moves with
//!   `Migrate { t: acked, history }` — zero frames dropped, outputs
//!   bit-identical to never having moved;
//! * **shard loss** re-homes every orphaned session the same way and
//!   then re-sends the unacked tail, because the dead shard will never
//!   emit those outputs.
//!
//! Faults on one connection — truncated frames, version skew, a
//! mid-stream disconnect — answer with one typed `Err` (or just drop
//! that connection) and never touch sibling sessions.
//!
//! Liveness and survival (DESIGN.md §16): with
//! [`FrontPolicy::heartbeat_ms`] on, a ticker probes every shard with
//! `Ping` each tick; a shard that stays silent for
//! [`FrontPolicy::miss_budget`] consecutive ticks is declared
//! *suspect* and its sessions migrate off while the socket is still
//! open.  Lost or suspect shards are re-dialed with exponential
//! backoff; a successful re-`Hello` re-admits the shard into
//! placement (`shard_rejoin`) and the cluster controller rebalances
//! streams back.  Recovery replays are budgeted: each session may
//! carry an optional client-declared deadline and is bounded by
//! [`FrontPolicy::retry_budget`] resent frames — past either, the
//! session is shed with a typed [`ErrCode::Overloaded`] instead of
//! replayed, and when fewer than [`FrontPolicy::min_live_shards`]
//! shards are reachable new admissions shed the same way.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::transport::{Listener, Transport, WireRead, WireWrite};
use super::wire::{role, write_msg, ErrCode, FrameReader, Msg, WireError, DRAIN_ALL, WIRE_VERSION};
use crate::obs::{Counter, ObsHandle, SpanKind, Telemetry, TraceCtx, TraceSampler};

/// One backend shard as the front-end sees it: a name for logs and a
/// way to reach it.
pub struct ShardLink {
    /// Human-readable shard name (logs and errors only).
    pub name: String,
    /// How to reach the shard.
    pub transport: Box<dyn Transport>,
}

/// Front-end admission policy.
#[derive(Debug, Clone, Copy)]
pub struct FrontPolicy {
    /// Sessions admitted across the whole fleet; the next new session
    /// is refused with [`ErrCode::AdmissionDenied`].
    pub max_sessions: usize,
    /// Trace every `n`th forwarded frame end to end (DESIGN.md §15);
    /// 0 — the default — disables tracing entirely and keeps wire
    /// encodings byte-identical to untraced `soi.wire.v1`.
    pub trace_sample_n: u64,
    /// Heartbeat tick interval in milliseconds; 0 — the default —
    /// disables liveness probing entirely (no `Ping` ever hits the
    /// wire, encodings stay plain `soi.wire.v1`).
    pub heartbeat_ms: u64,
    /// Consecutive silent ticks before a still-connected shard is
    /// declared suspect and its sessions migrate off (DESIGN.md §16).
    pub miss_budget: u32,
    /// Frames one session may have re-sent across recovery replays
    /// before it is shed with [`ErrCode::Overloaded`].
    pub retry_budget: u64,
    /// Reachable shards required to admit new sessions; below this
    /// the front runs degraded and sheds admissions with
    /// [`ErrCode::Overloaded`].
    pub min_live_shards: usize,
}

impl Default for FrontPolicy {
    fn default() -> Self {
        FrontPolicy {
            max_sessions: 64,
            trace_sample_n: 0,
            heartbeat_ms: 0,
            miss_budget: 3,
            retry_budget: 1024,
            min_live_shards: 1,
        }
    }
}

/// What the front-end counted over its lifetime.
#[derive(Debug, Clone, Copy, Default)]
pub struct FrontReport {
    /// Client connections accepted.
    pub conns: u64,
    /// Sessions admitted.
    pub admitted: u64,
    /// Sessions refused by [`FrontPolicy::max_sessions`].
    pub denied: u64,
    /// Client frames forwarded to shards.
    pub frames_in: u64,
    /// Output frames forwarded back to clients.
    pub frames_out: u64,
    /// Warm migrations completed (planned and crash-driven).
    pub migrations: u64,
    /// Shard connections lost.
    pub shard_losses: u64,
    /// Typed wire faults observed on either side.
    pub wire_errs: u64,
    /// Heartbeat ticks where a shard had an unanswered `Ping`.
    pub heartbeat_misses: u64,
    /// Shards declared suspect after exhausting the miss budget.
    pub shard_suspects: u64,
    /// Shards re-admitted into placement after a reconnect.
    pub shard_rejoins: u64,
    /// Frames re-sent by recovery replays.
    pub frames_retried: u64,
    /// Sessions/admissions shed with [`ErrCode::Overloaded`].
    pub shed: u64,
}

/// A freshly handshaken shard connection: buffered reader, write
/// half, and the `(feat, period, warmup)` shape the shard announced.
type ShardDuplex = (FrameReader<Box<dyn WireRead>>, Box<dyn WireWrite>, (u32, u32, u32));

/// Everything the router can be woken by.
enum FrontEvent {
    /// Acceptor registered a new client connection's write half.
    NewConn(u64, Box<dyn WireWrite>),
    /// A client connection's reader produced a message (or died).
    FromClient(u64, Result<Option<Msg>, WireError>),
    /// A shard connection's reader produced a message (or died).  The
    /// epoch stamps which connection generation the reader belongs
    /// to; events from a connection that predates a rejoin are stale
    /// and dropped.
    FromShard(usize, u64, Result<Option<Msg>, WireError>),
    /// Heartbeat tick: probe liveness, judge suspects, drive rejoins.
    Tick,
    /// A rejoin attempt finished (`None`: dial or handshake failed).
    Rejoined(usize, Option<ShardDuplex>),
    /// Operator command: move `session` to shard `to`.
    Migrate { session: u64, to: usize },
    /// Operator command: move one session off shard `from` onto `to`
    /// (the cluster controller's actuator — it names shards, not
    /// sessions).
    Rebalance { from: usize, to: usize },
    /// Shut down: drain shards, close connections, report.
    Stop,
}

struct ShardConn {
    name: String,
    writer: Box<dyn WireWrite>,
    /// Retained so a lost shard can be re-dialed for rejoin.
    transport: Arc<dyn Transport>,
    /// Cleared on the first failed write; its reader soon reports too.
    reachable: bool,
    /// Set once [`lose_shard`] has re-homed the orphans, whichever of
    /// the write or read side noticed the death first.
    lost: bool,
    /// Connection generation; bumped on every rejoin so reader events
    /// from a dead connection cannot be misattributed to the new one.
    epoch: u64,
    /// `Ping`s sent since the last `Pong` (consecutive silent ticks).
    pending_pings: u32,
    /// Next `Ping` seq.
    next_ping: u64,
    /// Ticks to wait before the next rejoin attempt.
    rejoin_wait: u64,
    /// Current backoff width in ticks; doubles per failed attempt.
    rejoin_backoff: u64,
    /// Rejoin attempts since the shard was first lost.
    rejoin_attempts: u64,
    /// A rejoin dial/handshake is running on a helper thread.
    rejoin_inflight: bool,
}

struct ConnState {
    writer: Box<dyn WireWrite>,
    greeted: bool,
}

struct SessionState {
    conn: u64,
    shard: usize,
    /// Next input seq expected from the client.
    next_seq: u64,
    /// Frames sent to the shard (== seq of the next frame to send).
    sent: u64,
    /// Frames whose output came back.
    acked: u64,
    /// Last `warmup` acked frames — the §9 replay window.
    history: VecDeque<Vec<f32>>,
    /// Sent-but-unacked frames, oldest first: `(seq, last, samples)`.
    inflight: VecDeque<(u64, bool, Vec<f32>)>,
    /// Frames held back while a planned migration waits for the
    /// inflight window to drain.
    held: VecDeque<(u64, bool, Vec<f32>)>,
    /// Planned migration target, if one is pending.
    migrating_to: Option<usize>,
    /// Frames re-sent by recovery replays, counted against
    /// [`FrontPolicy::retry_budget`].
    retries: u64,
    /// Client-declared recovery deadline (µs since last progress);
    /// the latest frame's declaration wins.
    deadline_us: Option<u64>,
    /// Last time an output was delivered (admission time initially) —
    /// the reference point for the deadline.
    last_progress: Instant,
}

/// A running front-end.  Dropping the handle abandons the router;
/// call [`FrontHandle::stop`] for a clean shutdown and its report.
pub struct FrontHandle {
    tx: Sender<FrontEvent>,
    router: Option<JoinHandle<FrontReport>>,
    listener: Arc<dyn Listener>,
}

impl FrontHandle {
    /// Nominate a planned warm migration of `session` onto `to_shard`.
    /// Executed asynchronously; invalid targets are ignored.
    pub fn migrate(&self, session: u64, to_shard: usize) -> Result<()> {
        self.tx
            .send(FrontEvent::Migrate {
                session,
                to: to_shard,
            })
            .map_err(|_| anyhow!("front router is gone"))
    }

    /// Execute a cluster-controller decision: move one session off
    /// shard `from` onto shard `to`.
    pub fn rebalance(&self, from: usize, to: usize) -> Result<()> {
        self.tx
            .send(FrontEvent::Rebalance { from, to })
            .map_err(|_| anyhow!("front router is gone"))
    }

    /// Stop accepting, drain every shard, and return the report.
    pub fn stop(mut self) -> Result<FrontReport> {
        let _ = self.tx.send(FrontEvent::Stop);
        self.listener.close();
        let handle = self.router.take().expect("router joined once");
        handle.join().map_err(|_| anyhow!("front router panicked"))
    }
}

/// Connect to every shard, verify they serve the same model shape,
/// and start the acceptor + router.  Fails fast if any shard is
/// unreachable, speaks another wire version, or disagrees on
/// `(feat, period, warmup)`.
pub fn spawn_front(
    listener: Box<dyn Listener>,
    shards: Vec<ShardLink>,
    policy: FrontPolicy,
) -> Result<FrontHandle> {
    spawn_front_with(listener, shards, policy, None)
}

/// [`spawn_front`] with telemetry: the router records its wire
/// counters, admission spans, and migration spans through the root's
/// shared handle, so a front-end exports the same `soi.obs.v1` feed a
/// shard does and `soi aggregate-feeds` can merge both sides.
pub fn spawn_front_with(
    listener: Box<dyn Listener>,
    shards: Vec<ShardLink>,
    policy: FrontPolicy,
    telemetry: Option<Arc<Telemetry>>,
) -> Result<FrontHandle> {
    if shards.is_empty() {
        bail!("front needs at least one shard");
    }
    let (tx, rx) = channel::<FrontEvent>();

    // Handshake each shard synchronously: we speak first.
    let mut shard_conns = Vec::with_capacity(shards.len());
    let mut shape: Option<(u32, u32, u32)> = None;
    for (idx, link) in shards.into_iter().enumerate() {
        let transport: Arc<dyn Transport> = Arc::from(link.transport);
        let (r, mut w) = transport
            .connect()
            .map_err(|e| anyhow!("shard '{}' unreachable: {e}", link.name))?;
        let hello = Msg::Hello {
            version: WIRE_VERSION,
            role: role::FRONT,
            feat: 0,
            period: 0,
            warmup: 0,
        };
        write_msg(&mut w, &hello).map_err(|e| anyhow!("shard '{}': {e}", link.name))?;
        let mut reader = FrameReader::new(r);
        let ack = reader
            .next_msg()
            .map_err(|e| anyhow!("shard '{}' handshake: {e}", link.name))?
            .with_context(|| format!("shard '{}' closed during handshake", link.name))?;
        let Msg::Hello {
            role: r_role,
            feat,
            period,
            warmup,
            ..
        } = ack
        else {
            bail!("shard '{}' greeted with {}", link.name, ack.kind());
        };
        if r_role != role::SHARD {
            bail!("shard '{}' claims role {r_role}, expected shard", link.name);
        }
        match shape {
            None => shape = Some((feat, period, warmup)),
            Some(s) if s != (feat, period, warmup) => bail!(
                "shard '{}' serves feat/period/warmup {:?}, fleet serves {:?}",
                link.name,
                (feat, period, warmup),
                s
            ),
            Some(_) => {}
        }
        // Reader thread keeps the (already buffered) FrameReader.
        spawn_shard_reader(idx, 0, reader, tx.clone());
        shard_conns.push(ShardConn {
            name: link.name,
            writer: w,
            transport,
            reachable: true,
            lost: false,
            epoch: 0,
            pending_pings: 0,
            next_ping: 0,
            rejoin_wait: 0,
            rejoin_backoff: 1,
            rejoin_attempts: 0,
            rejoin_inflight: false,
        });
    }
    let (feat, period, warmup) = shape.expect("nonempty fleet");

    // Acceptor: register the write half, then stream reads.
    let listener: Arc<dyn Listener> = Arc::from(listener);
    let accept_tx = tx.clone();
    let accept_listener = listener.clone();
    thread::spawn(move || {
        let mut next_conn = 0u64;
        loop {
            let (r, w) = match accept_listener.accept() {
                Ok(d) => d,
                Err(_) => return,
            };
            let id = next_conn;
            next_conn += 1;
            if accept_tx.send(FrontEvent::NewConn(id, w)).is_err() {
                return;
            }
            let conn_tx = accept_tx.clone();
            thread::spawn(move || {
                pump_reader(FrameReader::new(r), move |item| {
                    let fatal = is_fatal(&item);
                    conn_tx.send(FrontEvent::FromClient(id, item)).is_err() || fatal
                })
            });
        }
    });

    // Heartbeat ticker: wakes the router to probe shard liveness and
    // drive rejoins.  Exits once the router drops its receiver.
    if policy.heartbeat_ms > 0 {
        let tick_tx = tx.clone();
        let ms = policy.heartbeat_ms;
        thread::spawn(move || loop {
            thread::sleep(Duration::from_millis(ms));
            if tick_tx.send(FrontEvent::Tick).is_err() {
                return;
            }
        });
    }

    let fo = FrontObs {
        obs: telemetry.map(|t| t.shared()),
        sampler: TraceSampler::new(policy.trace_sample_n),
    };
    let router_tx = tx.clone();
    let router = thread::spawn(move || {
        run_router(rx, router_tx, shard_conns, policy, fo, feat, period, warmup)
    });
    Ok(FrontHandle {
        tx,
        router: Some(router),
        listener,
    })
}

/// Spawn the reader thread for shard `idx`'s connection generation
/// `epoch`; shared by the initial handshake and every rejoin.
fn spawn_shard_reader(
    idx: usize,
    epoch: u64,
    reader: FrameReader<Box<dyn WireRead>>,
    tx: Sender<FrontEvent>,
) {
    thread::spawn(move || {
        pump_reader(reader, move |item| {
            let fatal = is_fatal(&item);
            tx.send(FrontEvent::FromShard(idx, epoch, item)).is_err() || fatal
        })
    });
}

/// Dial + handshake one shard for rejoin: the front speaks first, the
/// shard must ack as [`role::SHARD`].  Runs on a helper thread so a
/// half-up endpoint never blocks the router.
fn connect_shard(transport: &dyn Transport) -> Result<ShardDuplex, WireError> {
    let (r, mut w) = transport.connect()?;
    let hello = Msg::Hello {
        version: WIRE_VERSION,
        role: role::FRONT,
        feat: 0,
        period: 0,
        warmup: 0,
    };
    write_msg(&mut w, &hello)?;
    let mut reader = FrameReader::new(r);
    let ack = reader.next_msg()?.ok_or(WireError::Closed)?;
    match ack {
        Msg::Hello {
            role: r_role,
            feat,
            period,
            warmup,
            ..
        } if r_role == role::SHARD => Ok((reader, w, (feat, period, warmup))),
        other => Err(WireError::Malformed {
            reason: format!("rejoin handshake: shard greeted with {}", other.kind()),
        }),
    }
}

/// Drive a [`FrameReader`] until `deliver` says stop (it returns true
/// on fatal items or when the router is gone).
fn pump_reader<R: super::transport::WireRead + 'static>(
    mut reader: FrameReader<R>,
    mut deliver: impl FnMut(Result<Option<Msg>, WireError>) -> bool,
) {
    loop {
        if deliver(reader.next_msg()) {
            return;
        }
    }
}

/// A reader item after which the byte stream cannot continue.
fn is_fatal(item: &Result<Option<Msg>, WireError>) -> bool {
    match item {
        Ok(None) => true,
        Ok(Some(_)) => false,
        Err(e) => !matches!(
            e,
            WireError::UnknownTag { .. }
                | WireError::Malformed { .. }
                | WireError::VersionSkew { .. }
        ),
    }
}

/// The router's observability state: one recording handle (when
/// telemetry is on) plus the head-based trace sampler (DESIGN.md §15).
/// Owned by the router thread; nothing here is shared or locked beyond
/// the handle's own per-record mutex.
struct FrontObs {
    obs: Option<ObsHandle>,
    sampler: TraceSampler,
}

impl FrontObs {
    fn count(&self, c: Counter, n: u64) {
        if let Some(h) = &self.obs {
            h.count(c, n);
        }
    }

    /// Head sampling: every `n`th forwarded frame opens a trace.  The
    /// root `front_admit` span is recorded here; the returned context
    /// rides the `Frame` to the owning shard.
    fn sample_frame(&mut self, session: u64, seq: u64, shard: usize) -> Option<TraceCtx> {
        let id = self.sampler.sample()?;
        if let Some(h) = &self.obs {
            h.span(id, SpanKind::FrontAdmit, 0, session, seq, shard as u64);
        }
        Some(TraceCtx::root(id, SpanKind::FrontAdmit))
    }

    /// Migrations are rare and exactly what an operator wants linked:
    /// when sampling is on at all, every migration opens a trace.
    fn trace_migration(&mut self, session: u64, from: usize, to: usize) -> Option<TraceCtx> {
        if !self.sampler.enabled() {
            return None;
        }
        let id = self.sampler.force();
        if let Some(h) = &self.obs {
            h.span(
                id,
                SpanKind::MigrateFront,
                0,
                session,
                from as u64,
                to as u64,
            );
        }
        Some(TraceCtx::root(id, SpanKind::MigrateFront))
    }

    /// Recovery replays are rare and always worth linking: when
    /// sampling is on at all, every re-home records a `front_retry`
    /// root span naming the session, tail size, and new home.
    fn trace_retry(&mut self, session: u64, resent: u64, shard: usize) {
        if !self.sampler.enabled() {
            return;
        }
        let id = self.sampler.force();
        if let Some(h) = &self.obs {
            h.span(id, SpanKind::FrontRetry, 0, session, resent, shard as u64);
        }
    }

    /// Every re-admission records a `shard_rejoin` root span naming
    /// the shard and how many dials it took.
    fn trace_rejoin(&mut self, shard: usize, attempts: u64) {
        if !self.sampler.enabled() {
            return;
        }
        let id = self.sampler.force();
        if let Some(h) = &self.obs {
            h.span(id, SpanKind::ShardRejoin, 0, shard as u64, attempts, 0);
        }
    }
}

fn send_to_shard(shards: &mut [ShardConn], idx: usize, msg: &Msg, fo: &FrontObs) -> bool {
    let s = &mut shards[idx];
    if !s.reachable {
        return false;
    }
    match write_msg(s.writer.as_mut(), msg) {
        Ok(n) => {
            if let Some(h) = &fo.obs {
                h.with(|o| {
                    o.count(Counter::WireTxMsgs, 1);
                    o.count(Counter::WireTxBytes, n as u64);
                });
            }
            true
        }
        Err(_) => {
            s.reachable = false;
            false
        }
    }
}

fn send_to_conn(conns: &mut HashMap<u64, ConnState>, id: u64, msg: &Msg, fo: &FrontObs) {
    // Typed errors sent to a client count under the total and their
    // own per-code counter (DESIGN.md appendix A, additive change).
    if let (Msg::Err { code, .. }, Some(h)) = (msg, &fo.obs) {
        let code = *code;
        h.with(|o| {
            o.count(Counter::WireErrs, 1);
            o.count(code.counter(), 1);
        });
    }
    if let Some(c) = conns.get_mut(&id) {
        // A failed client write surfaces as EOF on its reader; nothing
        // more to do here.
        if let Ok(n) = write_msg(c.writer.as_mut(), msg) {
            if let Some(h) = &fo.obs {
                h.with(|o| {
                    o.count(Counter::WireTxMsgs, 1);
                    o.count(Counter::WireTxBytes, n as u64);
                });
            }
        }
    }
}

fn load_of(sessions: &HashMap<u64, SessionState>, shard: usize) -> usize {
    sessions.values().filter(|s| s.shard == shard).count()
}

/// Least-loaded reachable shard, optionally excluding one.
fn pick_shard(
    shards: &[ShardConn],
    sessions: &HashMap<u64, SessionState>,
    exclude: Option<usize>,
) -> Option<usize> {
    shards
        .iter()
        .enumerate()
        .filter(|(i, s)| s.reachable && Some(*i) != exclude)
        .min_by_key(|(i, _)| load_of(sessions, *i))
        .map(|(i, _)| i)
}

#[allow(clippy::too_many_arguments)]
fn run_router(
    rx: Receiver<FrontEvent>,
    tx: Sender<FrontEvent>,
    mut shards: Vec<ShardConn>,
    policy: FrontPolicy,
    mut fo: FrontObs,
    feat: u32,
    period: u32,
    warmup: u32,
) -> FrontReport {
    let mut conns: HashMap<u64, ConnState> = HashMap::new();
    let mut sessions: HashMap<u64, SessionState> = HashMap::new();
    let mut report = FrontReport::default();

    for ev in rx {
        match ev {
            FrontEvent::NewConn(id, writer) => {
                report.conns += 1;
                conns.insert(
                    id,
                    ConnState {
                        writer,
                        greeted: false,
                    },
                );
            }
            FrontEvent::FromClient(conn, item) => match item {
                Ok(Some(msg)) => {
                    fo.count(Counter::WireRxMsgs, 1);
                    handle_client_msg(
                        conn,
                        msg,
                        &mut conns,
                        &mut sessions,
                        &mut shards,
                        &policy,
                        &mut fo,
                        feat,
                        period,
                        warmup,
                        &mut report,
                    );
                }
                Ok(None) => {
                    drop_conn(conn, &mut conns, &mut sessions, &mut shards, &fo);
                }
                Err(e) => {
                    report.wire_errs += 1;
                    if is_fatal(&Err(e.clone())) {
                        // No Err goes back out, so the fault is counted
                        // here rather than by send_to_conn.
                        fo.count(Counter::WireErrs, 1);
                        drop_conn(conn, &mut conns, &mut sessions, &mut shards, &fo);
                    } else {
                        let code = if matches!(e, WireError::VersionSkew { .. }) {
                            ErrCode::VersionSkew
                        } else {
                            ErrCode::BadFrame
                        };
                        send_to_conn(
                            &mut conns,
                            conn,
                            &Msg::Err {
                                code,
                                session: 0,
                                detail: e.to_string(),
                            },
                            &fo,
                        );
                    }
                }
            },
            FrontEvent::FromShard(idx, epoch, item) => {
                if epoch != shards[idx].epoch {
                    // Stale reader event from a connection generation
                    // that predates a rejoin; drop it.
                    continue;
                }
                match item {
                    Ok(Some(msg)) => {
                        fo.count(Counter::WireRxMsgs, 1);
                        handle_shard_msg(
                            idx,
                            msg,
                            &mut conns,
                            &mut sessions,
                            &mut shards,
                            &policy,
                            &mut fo,
                            feat,
                            warmup,
                            &mut report,
                        );
                    }
                    Ok(None) => {
                        lose_shard(
                            idx,
                            &mut conns,
                            &mut sessions,
                            &mut shards,
                            &policy,
                            &mut fo,
                            feat,
                            &mut report,
                        );
                    }
                    Err(e) => {
                        report.wire_errs += 1;
                        fo.count(Counter::WireErrs, 1);
                        if is_fatal(&Err(e)) {
                            lose_shard(
                                idx,
                                &mut conns,
                                &mut sessions,
                                &mut shards,
                                &policy,
                                &mut fo,
                                feat,
                                &mut report,
                            );
                        }
                    }
                }
            }
            FrontEvent::Tick => {
                heartbeat_tick(
                    &tx,
                    &mut conns,
                    &mut sessions,
                    &mut shards,
                    &policy,
                    &mut fo,
                    feat,
                    &mut report,
                );
            }
            FrontEvent::Rejoined(idx, conn) => {
                finish_rejoin(
                    idx,
                    conn,
                    &tx,
                    &mut shards,
                    &mut fo,
                    (feat, period, warmup),
                    &mut report,
                );
            }
            FrontEvent::Migrate { session, to } => {
                start_migration(
                    session,
                    to,
                    &mut conns,
                    &mut sessions,
                    &mut shards,
                    &policy,
                    &mut fo,
                    feat,
                    &mut report,
                );
            }
            FrontEvent::Rebalance { from, to } => {
                // Prefer a quiet session (empty inflight) so the move
                // completes immediately.
                let pick = sessions
                    .iter()
                    .filter(|(_, s)| s.shard == from && s.migrating_to.is_none())
                    .min_by_key(|(_, s)| s.inflight.len())
                    .map(|(id, _)| *id);
                if let Some(sid) = pick {
                    start_migration(
                        sid,
                        to,
                        &mut conns,
                        &mut sessions,
                        &mut shards,
                        &policy,
                        &mut fo,
                        feat,
                        &mut report,
                    );
                }
            }
            FrontEvent::Stop => break,
        }
    }

    for idx in 0..shards.len() {
        send_to_shard(&mut shards, idx, &Msg::Drain { session: DRAIN_ALL }, &fo);
        shards[idx].writer.shutdown();
    }
    for c in conns.values_mut() {
        c.writer.shutdown();
    }
    report
}

/// Longest rejoin backoff, in heartbeat ticks.
const MAX_REJOIN_BACKOFF: u64 = 32;

/// One heartbeat tick (DESIGN.md §16): probe live shards with `Ping`,
/// declare those past the miss budget suspect and migrate their
/// sessions off while the socket is still open, and drive
/// backoff-gated rejoin attempts for lost shards.
#[allow(clippy::too_many_arguments)]
fn heartbeat_tick(
    tx: &Sender<FrontEvent>,
    conns: &mut HashMap<u64, ConnState>,
    sessions: &mut HashMap<u64, SessionState>,
    shards: &mut [ShardConn],
    policy: &FrontPolicy,
    fo: &mut FrontObs,
    feat: u32,
    report: &mut FrontReport,
) {
    for idx in 0..shards.len() {
        if shards[idx].lost {
            maybe_rejoin(idx, tx, shards);
            continue;
        }
        if !shards[idx].reachable {
            continue; // write side died; the reader reports shortly
        }
        if shards[idx].pending_pings > 0 {
            report.heartbeat_misses += 1;
            fo.count(Counter::HeartbeatMiss, 1);
        }
        if shards[idx].pending_pings >= policy.miss_budget {
            // Stalled but still connected: declare it suspect and move
            // the sessions off before the socket dies on its own.
            report.shard_suspects += 1;
            fo.count(Counter::ShardSuspect, 1);
            lose_shard(idx, conns, sessions, shards, policy, fo, feat, report);
            continue;
        }
        let seq = shards[idx].next_ping;
        shards[idx].next_ping += 1;
        shards[idx].pending_pings += 1;
        if !send_to_shard(shards, idx, &Msg::Ping { seq }, fo) {
            lose_shard(idx, conns, sessions, shards, policy, fo, feat, report);
        }
    }
}

/// Start one rejoin attempt for a lost shard if its backoff window
/// has elapsed and no attempt is already running.  The dial +
/// handshake run on a helper thread and answer with
/// [`FrontEvent::Rejoined`] so a half-up endpoint never blocks the
/// router.
fn maybe_rejoin(idx: usize, tx: &Sender<FrontEvent>, shards: &mut [ShardConn]) {
    let s = &mut shards[idx];
    if s.rejoin_inflight {
        return;
    }
    if s.rejoin_wait > 0 {
        s.rejoin_wait -= 1;
        return;
    }
    s.rejoin_inflight = true;
    s.rejoin_attempts += 1;
    let transport = Arc::clone(&s.transport);
    let tx = tx.clone();
    thread::spawn(move || {
        let conn = connect_shard(transport.as_ref()).ok();
        let _ = tx.send(FrontEvent::Rejoined(idx, conn));
    });
}

/// A rejoin attempt came back: on success (and a matching model
/// shape) re-admit the shard into placement under a new connection
/// epoch; on failure widen the backoff.
fn finish_rejoin(
    idx: usize,
    conn: Option<ShardDuplex>,
    tx: &Sender<FrontEvent>,
    shards: &mut [ShardConn],
    fo: &mut FrontObs,
    fleet_shape: (u32, u32, u32),
    report: &mut FrontReport,
) {
    shards[idx].rejoin_inflight = false;
    let shape_ok = matches!(&conn, Some((_, _, shape)) if *shape == fleet_shape);
    let Some((reader, writer, _)) = conn.filter(|_| shape_ok) else {
        // Dial failed, handshake failed, or the endpoint now serves a
        // different model: back off and retry later.
        let s = &mut shards[idx];
        s.rejoin_wait = s.rejoin_backoff;
        s.rejoin_backoff = (s.rejoin_backoff * 2).min(MAX_REJOIN_BACKOFF);
        return;
    };
    let s = &mut shards[idx];
    s.epoch += 1;
    let epoch = s.epoch;
    s.writer = writer;
    s.reachable = true;
    s.lost = false;
    s.pending_pings = 0;
    s.rejoin_wait = 0;
    s.rejoin_backoff = 1;
    let attempts = s.rejoin_attempts;
    s.rejoin_attempts = 0;
    report.shard_rejoins += 1;
    fo.count(Counter::ShardRejoin, 1);
    fo.trace_rejoin(idx, attempts);
    spawn_shard_reader(idx, epoch, reader, tx.clone());
}

#[allow(clippy::too_many_arguments)]
fn handle_client_msg(
    conn: u64,
    msg: Msg,
    conns: &mut HashMap<u64, ConnState>,
    sessions: &mut HashMap<u64, SessionState>,
    shards: &mut [ShardConn],
    policy: &FrontPolicy,
    fo: &mut FrontObs,
    feat: u32,
    period: u32,
    warmup: u32,
    report: &mut FrontReport,
) {
    let greeted = conns.get(&conn).map(|c| c.greeted).unwrap_or(false);
    match msg {
        Msg::Hello { role: r, .. } => {
            if greeted || r != role::CLIENT {
                report.wire_errs += 1;
                send_to_conn(
                    conns,
                    conn,
                    &Msg::Err {
                        code: ErrCode::Protocol,
                        session: 0,
                        detail: "unexpected hello".into(),
                    },
                    fo,
                );
                return;
            }
            if let Some(c) = conns.get_mut(&conn) {
                c.greeted = true;
            }
            send_to_conn(
                conns,
                conn,
                &Msg::Hello {
                    version: WIRE_VERSION,
                    role: role::FRONT,
                    feat,
                    period,
                    warmup,
                },
                fo,
            );
        }
        Msg::Frame {
            session,
            seq,
            last,
            samples,
            deadline_us,
            ..
        } => {
            if !greeted {
                report.wire_errs += 1;
                send_to_conn(
                    conns,
                    conn,
                    &Msg::Err {
                        code: ErrCode::Protocol,
                        session,
                        detail: "frame before hello".into(),
                    },
                    fo,
                );
                return;
            }
            if samples.len() != feat as usize {
                report.wire_errs += 1;
                let detail = format!("frame has {} samples, feat is {feat}", samples.len());
                send_to_conn(
                    conns,
                    conn,
                    &Msg::Err {
                        code: ErrCode::BadFrame,
                        session,
                        detail,
                    },
                    fo,
                );
                return;
            }
            if !sessions.contains_key(&session) {
                // Admission: refuse before creating anything.
                if seq != 0 {
                    report.wire_errs += 1;
                    let detail = format!("unknown session starts at seq {seq}, expected 0");
                    send_to_conn(
                        conns,
                        conn,
                        &Msg::Err {
                            code: ErrCode::BadFrame,
                            session,
                            detail,
                        },
                        fo,
                    );
                    return;
                }
                if sessions.len() >= policy.max_sessions {
                    report.denied += 1;
                    report.wire_errs += 1;
                    let detail = format!("fleet serves {} sessions", policy.max_sessions);
                    send_to_conn(
                        conns,
                        conn,
                        &Msg::Err {
                            code: ErrCode::AdmissionDenied,
                            session,
                            detail,
                        },
                        fo,
                    );
                    return;
                }
                // Degraded mode: with fewer reachable shards than
                // policy demands, shed new admissions instead of
                // overloading the survivors (DESIGN.md §16).
                let live = shards.iter().filter(|s| s.reachable).count();
                if live < policy.min_live_shards {
                    report.shed += 1;
                    report.wire_errs += 1;
                    fo.count(Counter::AdmissionShed, 1);
                    let detail =
                        format!("fleet degraded: {live} of {} shards live", shards.len());
                    send_to_conn(
                        conns,
                        conn,
                        &Msg::Err {
                            code: ErrCode::Overloaded,
                            session,
                            detail,
                        },
                        fo,
                    );
                    return;
                }
                let Some(target) = pick_shard(shards, sessions, None) else {
                    report.wire_errs += 1;
                    send_to_conn(
                        conns,
                        conn,
                        &Msg::Err {
                            code: ErrCode::ShardLost,
                            session,
                            detail: "no reachable shard".into(),
                        },
                        fo,
                    );
                    return;
                };
                report.admitted += 1;
                sessions.insert(
                    session,
                    SessionState {
                        conn,
                        shard: target,
                        next_seq: 0,
                        sent: 0,
                        acked: 0,
                        history: VecDeque::new(),
                        inflight: VecDeque::new(),
                        held: VecDeque::new(),
                        migrating_to: None,
                        retries: 0,
                        deadline_us: None,
                        last_progress: Instant::now(),
                    },
                );
            }
            let sess = sessions.get_mut(&session).expect("just ensured");
            if sess.conn != conn {
                report.wire_errs += 1;
                send_to_conn(
                    conns,
                    conn,
                    &Msg::Err {
                        code: ErrCode::Protocol,
                        session,
                        detail: "session owned by another connection".into(),
                    },
                    fo,
                );
                return;
            }
            if seq != sess.next_seq {
                report.wire_errs += 1;
                let detail = format!("frame seq {seq}, expected {}", sess.next_seq);
                send_to_conn(
                    conns,
                    conn,
                    &Msg::Err {
                        code: ErrCode::BadFrame,
                        session,
                        detail,
                    },
                    fo,
                );
                return;
            }
            sess.next_seq += 1;
            report.frames_in += 1;
            // The deadline is a front-side recovery contract: the
            // latest declaration wins, and shards never see it.
            if deadline_us.is_some() {
                sess.deadline_us = deadline_us;
            }
            if sess.migrating_to.is_some() {
                sess.held.push_back((seq, last, samples));
                return;
            }
            let shard = sess.shard;
            sess.inflight.push_back((seq, last, samples.clone()));
            sess.sent += 1;
            // Only directly-forwarded frames are sampled; held frames
            // flushed after a migration replay ride untraced (the
            // migration itself carries its own forced trace).
            let frame = Msg::Frame {
                session,
                seq,
                last,
                samples,
                trace: fo.sample_frame(session, seq, shard),
                deadline_us: None,
            };
            if !send_to_shard(shards, shard, &frame, fo) {
                lose_shard(shard, conns, sessions, shards, policy, fo, feat, report);
            }
        }
        Msg::Drain { session } => {
            if session == DRAIN_ALL {
                let mine: Vec<u64> = sessions
                    .iter()
                    .filter(|(_, s)| s.conn == conn)
                    .map(|(id, _)| *id)
                    .collect();
                for sid in mine {
                    retire_session(sid, sessions, shards, fo);
                }
                return;
            }
            if sessions.get(&session).map(|s| s.conn) == Some(conn) {
                retire_session(session, sessions, shards, fo);
            }
        }
        Msg::Ping { seq } => {
            // Client-side liveness probe; answer even before hello.
            send_to_conn(conns, conn, &Msg::Pong { seq }, fo);
        }
        Msg::Pong { .. } => {
            // Late reply to nothing the front asked; ignore.
        }
        Msg::Migrate { .. } | Msg::FrameOut { .. } | Msg::Err { .. } => {
            report.wire_errs += 1;
            send_to_conn(
                conns,
                conn,
                &Msg::Err {
                    code: ErrCode::Protocol,
                    session: 0,
                    detail: "unexpected message".into(),
                },
                fo,
            );
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn handle_shard_msg(
    idx: usize,
    msg: Msg,
    conns: &mut HashMap<u64, ConnState>,
    sessions: &mut HashMap<u64, SessionState>,
    shards: &mut [ShardConn],
    policy: &FrontPolicy,
    fo: &mut FrontObs,
    feat: u32,
    warmup: u32,
    report: &mut FrontReport,
) {
    match msg {
        Msg::FrameOut {
            session,
            seq,
            samples,
            trace,
        } => {
            let Some(sess) = sessions.get_mut(&session) else {
                return; // retired while the output was in flight
            };
            if sess.shard != idx {
                return; // stale output from the pre-migration shard
            }
            let Some((fseq, last, frame)) = sess.inflight.pop_front() else {
                report.wire_errs += 1;
                fo.count(Counter::WireErrs, 1);
                return;
            };
            if fseq != seq {
                // The shard's absolute counter disagrees with ours —
                // a protocol bug, not a client fault.  Drop the pair.
                report.wire_errs += 1;
                fo.count(Counter::WireErrs, 1);
                return;
            }
            sess.acked += 1;
            sess.last_progress = Instant::now();
            sess.history.push_back(frame);
            while sess.history.len() > warmup as usize {
                sess.history.pop_front();
            }
            let conn = sess.conn;
            let finished = last;
            let move_now = sess.migrating_to.is_some() && sess.inflight.is_empty();
            report.frames_out += 1;
            // Close the loop on a traced frame: record the reply hop
            // and echo the extended context to the client.
            let reply_trace = trace.map(|ctx| {
                if let Some(h) = &fo.obs {
                    h.span(ctx.trace_id, SpanKind::FrontReply, ctx.kind, session, seq, 0);
                }
                ctx.child(SpanKind::FrontReply)
            });
            send_to_conn(
                conns,
                conn,
                &Msg::FrameOut {
                    session,
                    seq,
                    samples,
                    trace: reply_trace,
                },
                fo,
            );
            if finished {
                sessions.remove(&session);
                return;
            }
            if move_now {
                complete_migration(session, conns, sessions, shards, policy, fo, feat, report);
            }
        }
        Msg::Err {
            code,
            session,
            detail,
        } => {
            // Observed on receipt; forwarding it below counts the send
            // (total and per-code) in send_to_conn.
            report.wire_errs += 1;
            fo.count(Counter::WireErrs, 1);
            if session != 0 {
                if let Some(sess) = sessions.get(&session) {
                    let conn = sess.conn;
                    send_to_conn(
                        conns,
                        conn,
                        &Msg::Err {
                            code,
                            session,
                            detail,
                        },
                        fo,
                    );
                }
            }
        }
        Msg::Pong { .. } => {
            // Liveness reply: the shard answered everything we asked.
            shards[idx].pending_pings = 0;
        }
        // Shards never originate anything else after the handshake.
        Msg::Hello { .. }
        | Msg::Frame { .. }
        | Msg::Migrate { .. }
        | Msg::Drain { .. }
        | Msg::Ping { .. } => {
            report.wire_errs += 1;
            fo.count(Counter::WireErrs, 1);
        }
    }
}

/// Begin a planned migration; completes immediately when nothing is
/// in flight, otherwise when the last outstanding output arrives.
#[allow(clippy::too_many_arguments)]
fn start_migration(
    session: u64,
    to: usize,
    conns: &mut HashMap<u64, ConnState>,
    sessions: &mut HashMap<u64, SessionState>,
    shards: &mut [ShardConn],
    policy: &FrontPolicy,
    fo: &mut FrontObs,
    feat: u32,
    report: &mut FrontReport,
) {
    let Some(sess) = sessions.get_mut(&session) else {
        return;
    };
    if to >= shards.len() || !shards[to].reachable || to == sess.shard {
        return;
    }
    sess.migrating_to = Some(to);
    if sess.inflight.is_empty() {
        complete_migration(session, conns, sessions, shards, policy, fo, feat, report);
    }
}

/// The inflight window is empty: retire the session on the old shard,
/// re-create it on the target by §9 replay, and flush held frames.
#[allow(clippy::too_many_arguments)]
fn complete_migration(
    session: u64,
    conns: &mut HashMap<u64, ConnState>,
    sessions: &mut HashMap<u64, SessionState>,
    shards: &mut [ShardConn],
    policy: &FrontPolicy,
    fo: &mut FrontObs,
    feat: u32,
    report: &mut FrontReport,
) {
    let Some(sess) = sessions.get_mut(&session) else {
        return;
    };
    let Some(to) = sess.migrating_to.take() else {
        return;
    };
    let from = sess.shard;
    debug_assert!(sess.inflight.is_empty());
    send_to_shard(shards, from, &Msg::Drain { session }, fo);
    let hist: Vec<Vec<f32>> = sess.history.iter().cloned().collect();
    let t = sess.acked;
    let migrate = Msg::Migrate {
        session,
        t,
        feat,
        history: hist,
        trace: fo.trace_migration(session, from, to),
    };
    let sess = sessions.get_mut(&session).expect("still live");
    if !send_to_shard(shards, to, &migrate, fo) {
        // Target died at handoff.  The old shard already dropped the
        // session, so this is now a crash re-home, not a cancel.
        sess.shard = to;
        rehome_session(session, conns, sessions, shards, policy, fo, feat, report);
        return;
    }
    sess.shard = to;
    report.migrations += 1;
    // Stage every held frame as inflight *before* the first send: if
    // the target dies mid-flush, lose_shard re-homes the whole tail
    // instead of dropping whatever a local buffer still held (the
    // drain-vs-migration race — the old shard has already been sent
    // its Drain, so these frames exist nowhere else).
    let held: Vec<(u64, bool, Vec<f32>)> = sess.held.drain(..).collect();
    for (seq, last, samples) in &held {
        sess.inflight.push_back((*seq, *last, samples.clone()));
    }
    sess.sent += held.len() as u64;
    for (seq, last, samples) in held {
        let frame = Msg::Frame {
            session,
            seq,
            last,
            samples,
            trace: None,
            deadline_us: None,
        };
        if !send_to_shard(shards, to, &frame, fo) {
            // Every held frame is recorded inflight; losing the shard
            // now re-homes the session and re-sends the full tail.
            lose_shard(to, conns, sessions, shards, policy, fo, feat, report);
            return;
        }
    }
}

/// A shard died: mark it, cancel migrations that were *targeting* it,
/// and re-home every session *homed* on it by §9 replay — including a
/// re-send of the unacked tail, whose outputs the dead shard will
/// never deliver.
#[allow(clippy::too_many_arguments)]
fn lose_shard(
    idx: usize,
    conns: &mut HashMap<u64, ConnState>,
    sessions: &mut HashMap<u64, SessionState>,
    shards: &mut [ShardConn],
    policy: &FrontPolicy,
    fo: &mut FrontObs,
    feat: u32,
    report: &mut FrontReport,
) {
    if shards[idx].lost {
        return; // the other half (reader/writer) noticed first
    }
    shards[idx].lost = true;
    shards[idx].reachable = false;
    shards[idx].writer.shutdown();
    shards[idx].pending_pings = 0;
    report.shard_losses += 1;
    let nominated: Vec<u64> = sessions
        .iter()
        .filter(|(_, s)| s.shard != idx && s.migrating_to == Some(idx))
        .map(|(id, _)| *id)
        .collect();
    for sid in nominated {
        cancel_migration(sid, conns, sessions, shards, policy, fo, feat, report);
    }
    let orphans: Vec<u64> = sessions
        .iter()
        .filter(|(_, s)| s.shard == idx)
        .map(|(id, _)| *id)
        .collect();
    for sid in orphans {
        rehome_session(sid, conns, sessions, shards, policy, fo, feat, report);
    }
}

/// A planned migration's target died before the handoff: forget the
/// nomination and flush held frames to the still-live current shard.
#[allow(clippy::too_many_arguments)]
fn cancel_migration(
    session: u64,
    conns: &mut HashMap<u64, ConnState>,
    sessions: &mut HashMap<u64, SessionState>,
    shards: &mut [ShardConn],
    policy: &FrontPolicy,
    fo: &mut FrontObs,
    feat: u32,
    report: &mut FrontReport,
) {
    let Some(sess) = sessions.get_mut(&session) else {
        return;
    };
    sess.migrating_to = None;
    let shard = sess.shard;
    // Stage-then-send, exactly as complete_migration: if the current
    // shard dies mid-flush, the whole held tail is already inflight
    // and the re-home replays it — nothing is dropped.
    let held: Vec<(u64, bool, Vec<f32>)> = sess.held.drain(..).collect();
    for (seq, last, samples) in &held {
        sess.inflight.push_back((*seq, *last, samples.clone()));
    }
    sess.sent += held.len() as u64;
    for (seq, last, samples) in held {
        let frame = Msg::Frame {
            session,
            seq,
            last,
            samples,
            trace: None,
            deadline_us: None,
        };
        if !send_to_shard(shards, shard, &frame, fo) {
            lose_shard(shard, conns, sessions, shards, policy, fo, feat, report);
            return;
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn rehome_session(
    session: u64,
    conns: &mut HashMap<u64, ConnState>,
    sessions: &mut HashMap<u64, SessionState>,
    shards: &mut [ShardConn],
    policy: &FrontPolicy,
    fo: &mut FrontObs,
    feat: u32,
    report: &mut FrontReport,
) {
    loop {
        let Some(sess) = sessions.get_mut(&session) else {
            return;
        };
        sess.migrating_to = None;
        // Budgeted recovery (DESIGN.md §16): a session whose replay
        // would blow its retry budget, or whose client-declared
        // deadline has already passed since the last delivered
        // output, is shed with a typed `Overloaded` instead of
        // replayed — bounded work under cascading failures.
        let resend_n = (sess.inflight.len() + sess.held.len()) as u64;
        let over_deadline = sess
            .deadline_us
            .map_or(false, |d| sess.last_progress.elapsed().as_micros() as u64 > d);
        if over_deadline || sess.retries + resend_n > policy.retry_budget {
            let conn = sess.conn;
            let detail = if over_deadline {
                format!("recovery deadline exceeded after {} retried frames", sess.retries)
            } else {
                format!("retry budget {} exhausted", policy.retry_budget)
            };
            sessions.remove(&session);
            report.shed += 1;
            report.wire_errs += 1;
            fo.count(Counter::AdmissionShed, 1);
            send_to_conn(
                conns,
                conn,
                &Msg::Err {
                    code: ErrCode::Overloaded,
                    session,
                    detail,
                },
                fo,
            );
            return;
        }
        let Some(target) = pick_shard(shards, sessions, Some(sessions[&session].shard)) else {
            let conn = sessions[&session].conn;
            sessions.remove(&session);
            send_to_conn(
                conns,
                conn,
                &Msg::Err {
                    code: ErrCode::ShardLost,
                    session,
                    detail: "no reachable shard to resume on".into(),
                },
                fo,
            );
            return;
        };
        let sess = sessions.get_mut(&session).expect("still live");
        let from = sess.shard;
        let migrate = Msg::Migrate {
            session,
            t: sess.acked,
            feat,
            history: sess.history.iter().cloned().collect(),
            trace: fo.trace_migration(session, from, target),
        };
        if !send_to_shard(shards, target, &migrate, fo) {
            continue; // target just died too; try the next candidate
        }
        sess.shard = target;
        // Re-send everything the dead shard never acked, then held.
        let resend: Vec<(u64, bool, Vec<f32>)> = sess
            .inflight
            .iter()
            .cloned()
            .chain(sess.held.drain(..))
            .collect();
        sess.inflight.clear();
        for (seq, last, samples) in &resend {
            sess.inflight.push_back((*seq, *last, samples.clone()));
        }
        // Every replay attempt counts against the retry budget, even
        // one cut short by the target dying mid-replay — that bounds
        // total recovery work, not just successful recoveries.
        sess.retries += resend.len() as u64;
        report.frames_retried += resend.len() as u64;
        fo.count(Counter::FramesRetried, resend.len() as u64);
        fo.trace_retry(session, resend.len() as u64, target);
        let mut ok = true;
        for (seq, last, samples) in resend {
            let frame = Msg::Frame {
                session,
                seq,
                last,
                samples,
                trace: None,
                deadline_us: None,
            };
            if !send_to_shard(shards, target, &frame, fo) {
                ok = false;
                break;
            }
        }
        let sess = sessions.get_mut(&session).expect("still live");
        sess.sent = sess.acked + sess.inflight.len() as u64;
        if ok {
            report.migrations += 1;
            return;
        }
        // Target died mid-replay: loop and pick another.
    }
}

/// Forget a session and tell its shard to do the same.
fn retire_session(
    session: u64,
    sessions: &mut HashMap<u64, SessionState>,
    shards: &mut [ShardConn],
    fo: &FrontObs,
) {
    if let Some(sess) = sessions.remove(&session) {
        send_to_shard(shards, sess.shard, &Msg::Drain { session }, fo);
    }
}

/// Drop a client connection and retire every session it owned.
fn drop_conn(
    conn: u64,
    conns: &mut HashMap<u64, ConnState>,
    sessions: &mut HashMap<u64, SessionState>,
    shards: &mut [ShardConn],
    fo: &FrontObs,
) {
    if let Some(mut c) = conns.remove(&conn) {
        c.writer.shutdown();
    }
    let mine: Vec<u64> = sessions
        .iter()
        .filter(|(_, s)| s.conn == conn)
        .map(|(id, _)| *id)
        .collect();
    for sid in mine {
        retire_session(sid, sessions, shards, fo);
    }
}
