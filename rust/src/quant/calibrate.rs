//! Activation-scale calibration for quantized execution (DESIGN.md §10).
//!
//! [`calibrate`] runs the f32 reference network over synthesized
//! activations (the same `dsp::siggen` denoise distribution serving
//! traffic is drawn from) and records each quantization point's dynamic
//! range: the input frames, every conv layer's pre-activation output
//! (post-stride for S-CC layers, so only values the streaming schedule
//! actually computes are ranged), and each tconv extrapolation output.
//! Scales are `maxabs · MARGIN / 32767`, one per tensor; pre- and
//! post-activation ranges share the layer's scale (|ELU(x)| ≤ |x|),
//! which makes the positive half of the ELU LUT an exact identity.
//!
//! The calibration signal is not one random utterance: serving inputs
//! are speech/noise mixtures whose *peak* scales with the (random) mix
//! SNR, and an input range calibrated on a quiet draw would saturate on
//! a loud one (measured: a 1.6× amplitude mismatch collapses output SNR
//! from ~42 dB to ~30 dB, while ≤ 1.3× is absorbed by [`MARGIN`]).  So
//! the signal deliberately spans the serving distribution: consecutive
//! utterances mixed at the fixed SNR extremes and midpoints of
//! `siggen::denoise_pair`'s −5..10 dB range.
//!
//! The forward pass here is a deliberately small, self-contained f32
//! offline interpreter (the streaming == offline equivalence theorem
//! makes offline ranges valid for streaming execution); it exists so the
//! calibration can tap intermediates, which the serving backends never
//! expose.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::dsp::{frames, siggen};
use crate::runtime::engine::Weights;
use crate::runtime::manifest::{Manifest, QuantSpec};
use crate::util::rng::Rng;
use crate::util::tensor::Tensor;

use super::kernels::Q_ACT;

/// Headroom multiplier applied to every calibrated range: values up to
/// `MARGIN ×` the observed maximum survive without saturation, at a
/// fractional-LSB cost that is negligible next to the int8 weight noise
/// (measured in DESIGN.md §10).
pub const MARGIN: f32 = 1.25;

/// Derive a variant's [`QuantSpec`] by streaming `n_frames` synthesized
/// denoise-distribution frames (seeded by `seed`) through the f32
/// reference network and ranging every quantization point.
pub fn calibrate(
    manifest: &Manifest,
    weights: &Weights,
    n_frames: usize,
    seed: u64,
) -> Result<QuantSpec> {
    let cfg = &manifest.config;
    if cfg.interp.is_some() {
        bail!(
            "{}: interpolation variants are offline-only and have no \
             quantized executable",
            manifest.name
        );
    }
    if n_frames == 0 {
        bail!("{}: calibration needs at least one frame", manifest.name);
    }
    let mut rng = Rng::new(seed);
    // one utterance per fixed mix SNR, covering the serving range's
    // amplitude distribution (loud −5 dB mixtures set the input range)
    let snrs = [-5.0f64, 0.0, 5.0, 10.0];
    let seg = (cfg.feat * n_frames).div_ceil(snrs.len());
    let mut noisy = Vec::with_capacity(seg * snrs.len());
    for snr_db in snrs {
        let clean = siggen::speech(&mut rng, seg, siggen::FS);
        let nse = siggen::noise(&mut rng, seg, siggen::FS);
        noisy.extend(siggen::mix(&clean, &nse, snr_db));
    }
    let (cols, _) = frames(&noisy, cfg.feat);
    let t = cols.len();
    let mut x = Tensor::zeros(vec![cfg.feat, t]);
    for (tt, col) in cols.iter().enumerate() {
        for (i, &v) in col.iter().enumerate() {
            x.set2(i, tt, v);
        }
    }

    // parameter lookup by name, shape-checked against the config
    let by_name: BTreeMap<&str, usize> = manifest
        .params
        .iter()
        .enumerate()
        .map(|(i, s)| (s.name.as_str(), i))
        .collect();
    let param = |n: &str| -> Result<&Tensor> {
        let i = *by_name
            .get(n)
            .with_context(|| format!("{}: manifest lacks parameter {n}", manifest.name))?;
        Ok(&weights.tensors[i])
    };

    let depth = cfg.depth();
    let scale = |maxabs: f32| {
        if maxabs > 0.0 {
            maxabs * MARGIN / Q_ACT as f32
        } else {
            1.0
        }
    };
    let s_in = scale(maxabs(&x.data));

    // ---- encoder ----
    let mut enc: Vec<Tensor> = Vec::with_capacity(depth + 1);
    enc.push(x.clone());
    let mut cur = x;
    let mut s_enc = Vec::with_capacity(depth);
    for l in 1..=depth {
        if cfg.shift_pos == Some(l) {
            cur = delay_cols(&cur, cfg.shift);
        }
        let mut y = conv_full(&cur, param(&format!("enc{l}.w"))?, param(&format!("enc{l}.b"))?);
        if cfg.scc.contains(&l) {
            y = stride2(&y);
        }
        s_enc.push(scale(maxabs(&y.data)));
        elu(&mut y.data);
        cur = y.clone();
        enc.push(y);
    }

    // ---- decoder ----
    let mut s_dec = vec![1.0f32; depth];
    let mut s_up = BTreeMap::new();
    let mut d: Option<Tensor> = None;
    for l in (1..=depth).rev() {
        let inp = if l == depth {
            enc[depth].clone()
        } else {
            concat_rows(d.as_ref().unwrap(), &enc[l])
        };
        let mut y = conv_full(&inp, param(&format!("dec{l}.w"))?, param(&format!("dec{l}.b"))?);
        s_dec[l - 1] = scale(maxabs(&y.data));
        elu(&mut y.data);
        let mut dl = y;
        if cfg.scc.contains(&l) {
            let t_out = enc[l - 1].shape[1];
            if cfg.extrap_of(l) == "tconv" {
                let up = tconv_upsample(
                    &dl,
                    param(&format!("up{l}.w"))?,
                    param(&format!("up{l}.b"))?,
                    t_out,
                );
                s_up.insert(l, scale(maxabs(&up.data)));
                dl = up;
            } else {
                dl = duplicate_upsample(&dl, t_out);
            }
        }
        d = Some(dl);
    }

    let spec = QuantSpec {
        s_in,
        s_enc,
        s_dec,
        s_up,
    };
    spec.validate(cfg)
        .with_context(|| format!("{}: calibration produced an invalid spec", manifest.name))?;
    Ok(spec)
}

// ---- minimal f32 offline primitives (taps need intermediates the
// serving backends never expose; semantics mirror backend::native) ----

fn maxabs(v: &[f32]) -> f32 {
    v.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
}

fn elu(v: &mut [f32]) {
    for x in v.iter_mut() {
        if *x < 0.0 {
            *x = x.exp_m1();
        }
    }
}

/// Causal stride-1 conv over a whole (C_in, T) sequence.
fn conv_full(x: &Tensor, w: &Tensor, b: &Tensor) -> Tensor {
    let c_in = x.shape[0];
    let t = x.shape[1];
    let c_out = w.shape[0];
    let k = w.shape[2];
    let mut out = Tensor::zeros(vec![c_out, t]);
    for o in 0..c_out {
        for tt in 0..t {
            let mut acc = b.data[o];
            for i in 0..c_in {
                let wrow = &w.data[(o * c_in + i) * k..(o * c_in + i + 1) * k];
                for (j, wv) in wrow.iter().enumerate() {
                    let src = tt as isize + j as isize - (k as isize - 1);
                    if src >= 0 {
                        acc += wv * x.at2(i, src as usize);
                    }
                }
            }
            out.set2(o, tt, acc);
        }
    }
    out
}

/// Right-shift along time by `d` frames (zeros in front), same length.
fn delay_cols(x: &Tensor, d: usize) -> Tensor {
    let (c, t) = (x.shape[0], x.shape[1]);
    let mut out = Tensor::zeros(vec![c, t]);
    for i in 0..c {
        for tt in d..t {
            out.set2(i, tt, x.at2(i, tt - d));
        }
    }
    out
}

/// Keep even time steps: `out[:, s] = x[:, 2 s]`.
fn stride2(x: &Tensor) -> Tensor {
    let (c, t) = (x.shape[0], x.shape[1]);
    let t2 = (t + 1) / 2;
    let mut out = Tensor::zeros(vec![c, t2]);
    for i in 0..c {
        for s in 0..t2 {
            out.set2(i, s, x.at2(i, 2 * s));
        }
    }
    out
}

/// Stack `a` over `b` along the channel axis.
fn concat_rows(a: &Tensor, b: &Tensor) -> Tensor {
    debug_assert_eq!(a.shape[1], b.shape[1]);
    let t = a.shape[1];
    let c = a.shape[0] + b.shape[0];
    let mut data = Vec::with_capacity(c * t);
    data.extend_from_slice(&a.data);
    data.extend_from_slice(&b.data);
    Tensor::new(vec![c, t], data)
}

/// Duplication extrapolation: `up[:, t] = y[:, t / 2]`.
fn duplicate_upsample(y: &Tensor, t_out: usize) -> Tensor {
    let c = y.shape[0];
    let last = y.shape[1] - 1;
    let mut out = Tensor::zeros(vec![c, t_out]);
    for i in 0..c {
        for tt in 0..t_out {
            out.set2(i, tt, y.at2(i, (tt / 2).min(last)));
        }
    }
    out
}

/// Stride-2 transposed conv over a whole sequence (phase 0 on even
/// output times, phase 1 on odd ones).
fn tconv_upsample(y: &Tensor, w: &Tensor, b: &Tensor, t_out: usize) -> Tensor {
    let c_out = w.shape[0];
    let c_in = w.shape[1];
    let s = y.shape[1];
    let mut out = Tensor::zeros(vec![c_out, t_out]);
    for src in 0..s {
        for ph in 0..2usize {
            let dst = 2 * src + ph;
            if dst >= t_out {
                continue;
            }
            for o in 0..c_out {
                let mut acc = b.data[o];
                for i in 0..c_in {
                    acc += w.data[(o * c_in + i) * 2 + ph] * y.at2(i, src);
                }
                out.set2(o, dst, acc);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::synth;
    use crate::runtime::ModelConfig;

    fn cfg(scc: Vec<usize>, shift_pos: Option<usize>, extrap: &str) -> ModelConfig {
        ModelConfig {
            feat: 4,
            channels: vec![5, 6],
            kernel: 3,
            extrap: vec![extrap.into(); scc.len()],
            scc,
            shift_pos,
            shift: 1,
            interp: None,
        }
    }

    #[test]
    fn calibration_is_deterministic_and_valid() {
        for (c, name) in [
            (cfg(vec![], None, "duplicate"), "stmc"),
            (cfg(vec![2], None, "duplicate"), "scc2"),
            (cfg(vec![2], Some(2), "duplicate"), "sscc2"),
            (cfg(vec![2], None, "tconv"), "scc2_tconv"),
        ] {
            let m = synth::manifest(&c, name, 32);
            let w = synth::he_weights(&m, 0xFEED);
            let a = calibrate(&m, &w, 64, 7).unwrap();
            let b = calibrate(&m, &w, 64, 7).unwrap();
            assert_eq!(a, b, "{name}: calibration must be deterministic");
            a.validate(&c).unwrap();
            assert!(a.s_in > 0.0 && a.s_in < 1.0, "{name}: s_in {}", a.s_in);
            if c.extrap.first().map(|e| e == "tconv").unwrap_or(false) {
                assert!(a.s_up.contains_key(&2), "{name}: tconv scale baked");
            }
        }
    }

    #[test]
    fn deeper_seeds_change_ranges_but_not_validity() {
        let c = cfg(vec![2], None, "duplicate");
        let m = synth::manifest(&c, "scc2", 32);
        let w = synth::he_weights(&m, 0xFEED);
        let a = calibrate(&m, &w, 64, 7).unwrap();
        let b = calibrate(&m, &w, 64, 8).unwrap();
        assert_ne!(a, b, "different calibration signals range differently");
        b.validate(&c).unwrap();
    }

    #[test]
    fn rejects_interp_and_empty() {
        let mut c = cfg(vec![2], None, "duplicate");
        c.interp = Some("linear".into());
        let m = synth::manifest(&c, "interp", 32);
        let w = synth::he_weights(&m, 1);
        assert!(calibrate(&m, &w, 32, 1).is_err());
        let c2 = cfg(vec![], None, "duplicate");
        let m2 = synth::manifest(&c2, "stmc", 32);
        let w2 = synth::he_weights(&m2, 1);
        assert!(calibrate(&m2, &w2, 0, 1).is_err());
    }
}
