//! Quantized int8 execution subsystem (DESIGN.md §10).
//!
//! Precision is a *rung axis* of the serving ladder: every SOI variant
//! can be compiled either as the classic f32 interpreter or as
//! [`QuantVariant`] — int8 weights (per-channel scales refined per input
//! channel, packed as [`QTensor`]), s16 activations under static
//! calibrated scales, i32-accumulator group-dot GEMMs with fused
//! scale-combine + bias + LUT-based ELU.  Both executables implement the
//! same `VariantExec` trait and share one weight upload, so phase-aligned
//! batching (DESIGN.md §8), variant ladders and warm state migration
//! (§9) work unchanged across precisions — a ladder like
//! `stmc:f32 → stmc:int8 → scc2:int8` lets the load controller reach for
//! cheaper arithmetic *before* structural compression.
//!
//! * [`qtensor`] — the packed int8 weight format + quantizers.
//! * [`kernels`] — s16 requantization, the batched integer GEMM, the
//!   interpolated ELU LUT.
//! * [`calibrate`] — activation-range calibration over synthesized
//!   activations; produces the manifest's baked
//!   [`crate::runtime::manifest::QuantSpec`].
//! * [`exec`] — `QuantExec`: the streaming interpreter itself.
//!
//! The chosen numeric format (weights int8, activations s16 — the
//! CMSIS-NN s16 configuration) is driven by a measured accuracy ladder:
//! int8 activations cap the 7-layer U-Net's output SNR near 30 dB and
//! pure per-output-channel weight scales near 33 dB, while
//! input-channel-refined int8 weights with s16 activations hold ≥ 40 dB
//! on every synthesized variant family (DESIGN.md §10,
//! `rust/tests/quant_backend.rs`).

pub mod calibrate;
pub mod exec;
pub mod kernels;
pub mod qtensor;

pub use calibrate::calibrate;
pub use exec::QuantVariant;
pub use kernels::{EluLut, Q_ACT};
pub use qtensor::{quantize_groups, quantize_per_channel, quantize_weights, QTensor, Q_W};
