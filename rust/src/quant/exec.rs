//! `QuantExec`: the quantized executable form of a variant manifest —
//! the int8/s16 twin of `backend::native::NativeVariant`, implementing
//! the same [`VariantExec`] trait so the whole serving stack (schedulers,
//! phase-aligned batching, variant ladders, warm migration) runs
//! unchanged over quantized rungs (DESIGN.md §10).
//!
//! Execution model:
//!
//! * **Weights** are packed int8: quantized per-(out, in) channel
//!   ([`crate::quant::qtensor::QTensor`]) and then repacked into the
//!   [`crate::kernels::PackedI8`] microkernel panels — codes, combine
//!   factors and bias in lane-padded panel layout — prepared lazily from
//!   the shared f32 [`DeviceWeights`] upload on first use and cached
//!   (fingerprinted, so a ladder's one upload serves f32 and int8 rungs
//!   alike).
//! * **Activations** are s16 codes under the static per-tensor scales
//!   baked into the manifest's [`QuantSpec`] at calibration time.  They
//!   live in the ordinary f32 [`StateSet`] tensors (every code is a small
//!   integer, exactly representable), so state cloning, history replay
//!   and warm migration work bit-for-bit without a parallel state type.
//! * **Schedule** is byte-for-byte the same SOI phase logic as the f32
//!   interpreter — one batched code path, `B == 1` is the single-stream
//!   case, and per-stream accumulation order is batch-independent, so
//!   batched and sequential quantized serving are bit-identical
//!   (`rust/tests/quant_backend.rs`).  As in the f32 interpreter, the
//!   per-phase tick/fire/compute decisions are precompiled into plan
//!   tables and every intermediate comes from the variant's
//!   [`crate::kernels::StepArena`] — zero steady-state allocations
//!   (`rust/tests/hot_path_alloc.rs`).
//! * **Determinism**: integer dots, fixed-order f32 scale folds, f32
//!   `round` requantization and the integer ELU LUT — no execution-order
//!   freedom anywhere, *on any ISA*: the SIMD int8 kernels use unfused
//!   per-lane folds, so their output is bit-identical to the scalar
//!   reference (`rust/tests/properties.rs`), which keeps migration
//!   replay exact.
//!
//! The FP shift-at-layer-1 handoff slot is the one state tensor holding
//! real f32 values (the head's output frames); everything else holds
//! codes.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::backend::native::state_specs;
use crate::backend::{
    build_phase_plans, DeviceWeights, HostWeights, OutSink, PhasePlan, VariantExec,
};
use crate::kernels::{gemm_i8, next_arena_id, with_arena, ArenaSpec, PackedI8, StepArena};
use crate::runtime::engine::{StateSet, Weights};
use crate::runtime::manifest::{Dtype, Manifest, ModelConfig, QuantSpec, TensorSpec};
use crate::util::tensor::Tensor;

use super::kernels::{quantize_act, requant, EluLut};
use super::qtensor::quantize_weights;

/// Pre-resolved tensor indices (state slots and manifest parameters);
/// mirrors the f32 interpreter's layout.
struct QIndices {
    enc_win: Vec<usize>,
    dec_win: Vec<usize>,
    enc_w: Vec<usize>,
    enc_b: Vec<usize>,
    dec_w: Vec<usize>,
    dec_b: Vec<usize>,
    up_cache: BTreeMap<usize, usize>,
    up_w: BTreeMap<usize, usize>,
    up_b: BTreeMap<usize, usize>,
    shift_fifo: Option<usize>,
    fp_handoff: Option<usize>,
    head_w: usize,
    head_b: usize,
    n_params: usize,
}

/// Which part of an inference to run (the FP split).
#[derive(Clone, Copy, PartialEq, Eq)]
enum Part {
    All,
    Pre,
    Rest,
}

/// One conv layer's prepared quantized plan: the packed microkernel
/// panel (codes + per-(out, in) combine factors + bias, lane-padded).
struct QPlan {
    panel: PackedI8,
}

/// A quantized stride-2 transposed conv: one 1-tap panel per output
/// phase.
struct QUpPlan {
    phases: [PackedI8; 2],
}

/// Weight-dependent execution plan, cached per uploaded weight set.
struct Prepared {
    fingerprint: u64,
    enc: Vec<QPlan>,
    dec: Vec<QPlan>,
    up: BTreeMap<usize, QUpPlan>,
    head: QPlan,
}

/// Per-layer channel dimensions resolved at compile time.
struct LayerDims {
    enc_ci: usize,
    dec_ci: usize,
}

/// One variant compiled for quantized execution (dtype int8).
pub struct QuantVariant {
    cfg: ModelConfig,
    name: String,
    period: usize,
    depth: usize,
    is_scc: Vec<bool>,
    tconv: Vec<bool>,
    specs: Vec<TensorSpec>,
    idx: QIndices,
    qs: QuantSpec,
    /// Per-layer ELU LUTs (scale = the layer's shared pre/post scale).
    luts_enc: Vec<EluLut>,
    luts_dec: Vec<EluLut>,
    /// Input-activation scale of each encoder layer (index `l - 1`).
    enc_sx: Vec<f32>,
    /// Per-row input scales of each decoder layer (deep rows first).
    dec_sx: Vec<Vec<f32>>,
    /// Input scale of the head conv.
    head_sx: f32,
    dims: Vec<LayerDims>,
    plans: Vec<PhasePlan>,
    arena_id: u64,
    arena_spec: ArenaSpec,
    prepared: RwLock<Option<Arc<Prepared>>>,
    macs: AtomicU64,
}

impl QuantVariant {
    /// Compile (validate + index + plan) one int8 manifest for quantized
    /// execution.  The manifest must carry baked quant params.
    pub fn new(manifest: &Manifest) -> Result<QuantVariant> {
        let cfg = manifest.config.clone();
        let depth = cfg.depth();
        let name = manifest.name.clone();
        if depth == 0 {
            bail!("{name}: config has no layers");
        }
        if cfg.kernel == 0 {
            bail!("{name}: kernel must be >= 1");
        }
        if cfg.interp.is_some() {
            bail!(
                "{name}: interpolation variants are offline-only f32; no \
                 quantized executable exists for them"
            );
        }
        if manifest.dtype != Dtype::Int8 {
            bail!("{name}: QuantExec compiles dtype int8 manifests only");
        }
        let Some(qs) = manifest.quant.clone() else {
            bail!("{name}: int8 manifest lacks baked quant params");
        };
        qs.validate(&cfg)
            .with_context(|| format!("{name}: invalid quant spec"))?;
        if cfg.scc.windows(2).any(|w| w[0] >= w[1]) {
            bail!("{name}: scc positions must be sorted and unique");
        }
        if cfg.scc.iter().any(|&p| p == 0 || p > depth) {
            bail!("{name}: scc position out of range 1..={depth}");
        }
        if let Some(s) = cfg.shift_pos {
            if s == 0 || s > depth {
                bail!("{name}: shift_pos out of range 1..={depth}");
            }
            if cfg.shift == 0 {
                bail!("{name}: shift must be >= 1");
            }
        }
        if manifest.period != cfg.period() {
            bail!(
                "{name}: manifest period {} != 2^|scc| = {}",
                manifest.period,
                cfg.period()
            );
        }
        for &p in &cfg.scc {
            let e = cfg.extrap_of(p);
            if e != "duplicate" && e != "tconv" {
                bail!("{name}: unknown extrapolation '{e}' at S-CC {p}");
            }
        }

        let mut is_scc = vec![false; depth + 1];
        let mut tconv = vec![false; depth + 1];
        for l in 1..=depth {
            is_scc[l] = cfg.scc.contains(&l);
            tconv[l] = is_scc[l] && cfg.extrap_of(l) == "tconv";
        }

        let specs = state_specs(&cfg);
        let state_slot: BTreeMap<&str, usize> = specs
            .iter()
            .enumerate()
            .map(|(i, s)| (s.name.as_str(), i))
            .collect();
        let sslot = |n: &str| -> Result<usize> {
            state_slot
                .get(n)
                .copied()
                .with_context(|| format!("{name}: missing state slot {n}"))
        };
        let param_slot: BTreeMap<&str, usize> = manifest
            .params
            .iter()
            .enumerate()
            .map(|(i, s)| (s.name.as_str(), i))
            .collect();
        let pslot = |n: &str, shape: &[usize]| -> Result<usize> {
            let i = *param_slot
                .get(n)
                .with_context(|| format!("{name}: manifest lacks parameter {n}"))?;
            if manifest.params[i].shape != shape {
                bail!(
                    "{name}: parameter {n} has shape {:?}, quant backend expects {:?}",
                    manifest.params[i].shape,
                    shape
                );
            }
            Ok(i)
        };

        let k = cfg.kernel;
        let mut enc_win = Vec::new();
        let mut dec_win = Vec::new();
        let mut enc_w = Vec::new();
        let mut enc_b = Vec::new();
        let mut dec_w = Vec::new();
        let mut dec_b = Vec::new();
        for l in 1..=depth {
            enc_win.push(sslot(&format!("enc{l}.win"))?);
            dec_win.push(sslot(&format!("dec{l}.win"))?);
            enc_w.push(pslot(
                &format!("enc{l}.w"),
                &[cfg.enc_out_ch(l), cfg.enc_in_ch(l), k],
            )?);
            enc_b.push(pslot(&format!("enc{l}.b"), &[cfg.enc_out_ch(l)])?);
            dec_w.push(pslot(
                &format!("dec{l}.w"),
                &[cfg.dec_out_ch(l), cfg.dec_in_ch(l), k],
            )?);
            dec_b.push(pslot(&format!("dec{l}.b"), &[cfg.dec_out_ch(l)])?);
        }
        let mut up_cache = BTreeMap::new();
        let mut up_w = BTreeMap::new();
        let mut up_b = BTreeMap::new();
        for &p in &cfg.scc {
            up_cache.insert(p, sslot(&format!("up{p}.cache"))?);
            if tconv[p] {
                let c = cfg.dec_out_ch(p);
                up_w.insert(p, pslot(&format!("up{p}.w"), &[c, c, 2])?);
                up_b.insert(p, pslot(&format!("up{p}.b"), &[c])?);
            }
        }
        let shift_fifo = if cfg.shift_pos.is_some() {
            Some(sslot("shift.fifo")?)
        } else {
            None
        };
        let fp_handoff = match cfg.shift_pos {
            Some(s) if !cfg.scc.contains(&s) => Some(sslot("fp.handoff")?),
            _ => None,
        };
        let head_w = pslot("head.w", &[cfg.feat, cfg.dec_out_ch(1), 1])?;
        let head_b = pslot("head.b", &[cfg.feat])?;

        // ---- static scale tables + per-layer ELU LUTs ----
        let mut enc_sx = Vec::with_capacity(depth);
        for l in 1..=depth {
            enc_sx.push(if l == 1 { qs.s_in } else { qs.s_enc[l - 2] });
        }
        // scale of the deep rows of dec l (l < depth): the value of
        // d_{l+1} *as read* — the extrapolation cache's scale at an S-CC
        // position, the plain post-ELU scale otherwise (including through
        // the FP handoff, which parks the same tensor)
        let deep_scale = |l: usize| -> f32 {
            let u = l + 1;
            if is_scc[u] && tconv[u] {
                qs.s_up[&u]
            } else {
                qs.s_dec[u - 1]
            }
        };
        let mut dec_sx = Vec::with_capacity(depth);
        for l in 1..=depth {
            let c_in = cfg.dec_in_ch(l);
            let rows = if l == depth {
                vec![qs.s_enc[depth - 1]; c_in]
            } else {
                let c_deep = cfg.dec_out_ch(l + 1);
                let mut rows = vec![deep_scale(l); c_deep];
                rows.extend(std::iter::repeat(qs.s_enc[l - 1]).take(c_in - c_deep));
                rows
            };
            dec_sx.push(rows);
        }
        let head_sx = if is_scc[1] && tconv[1] {
            qs.s_up[&1]
        } else {
            qs.s_dec[0]
        };
        let luts_enc = qs.s_enc.iter().map(|&s| EluLut::new(s)).collect();
        let luts_dec = qs.s_dec.iter().map(|&s| EluLut::new(s)).collect();

        // ---- precompiled dims, phase plans, arena spec ----
        let mut dims = Vec::with_capacity(depth);
        let mut isizes = vec![cfg.feat];
        let mut fsizes = vec![cfg.feat];
        for l in 1..=depth {
            let (eci, eco) = (cfg.enc_in_ch(l), cfg.enc_out_ch(l));
            let (dci, dco) = (cfg.dec_in_ch(l), cfg.dec_out_ch(l));
            isizes.extend([eci, eci * k, eco, dci, dci * k, dco]);
            fsizes.extend([eco, dco]);
            dims.push(LayerDims {
                enc_ci: eci,
                dec_ci: dci,
            });
        }
        let period = cfg.period();
        let plans = build_phase_plans(&cfg);

        Ok(QuantVariant {
            period,
            idx: QIndices {
                enc_win,
                dec_win,
                enc_w,
                enc_b,
                dec_w,
                dec_b,
                up_cache,
                up_w,
                up_b,
                shift_fifo,
                fp_handoff,
                head_w,
                head_b,
                n_params: manifest.params.len(),
            },
            cfg,
            name,
            depth,
            is_scc,
            tconv,
            specs,
            qs,
            luts_enc,
            luts_dec,
            enc_sx,
            dec_sx,
            head_sx,
            dims,
            plans,
            arena_id: next_arena_id(),
            arena_spec: ArenaSpec::new(fsizes, isizes),
            prepared: RwLock::new(None),
            macs: AtomicU64::new(0),
        })
    }

    /// Resolve host weights from the backend-tagged handle.
    fn host<'a>(&self, dw: &'a DeviceWeights) -> Result<&'a HostWeights> {
        match dw {
            DeviceWeights::Host(hw) => {
                if hw.tensors().len() != self.idx.n_params {
                    bail!(
                        "{}: weights hold {} tensors, manifest wants {}",
                        self.name,
                        hw.tensors().len(),
                        self.idx.n_params
                    );
                }
                Ok(hw)
            }
            #[cfg(feature = "pjrt")]
            DeviceWeights::Pjrt(_) => {
                bail!("{}: pjrt device weights passed to the quant backend", self.name)
            }
        }
    }

    /// Quantize the uploaded f32 weights into packed microkernel panels,
    /// cached per weight set (fingerprinted: a re-upload — e.g. a pruning
    /// sweep — rebuilds the plan instead of silently executing stale
    /// codes).
    ///
    /// The key is a *content* fingerprint rather than an allocation
    /// identity on purpose: distinct `DeviceWeights` uploads of the same
    /// tensors (legal through the public API) must share the plan rather
    /// than evict each other's.  The hot path is the uncontended read
    /// lock plus ~17 bit-probes per tensor — noise next to one batched
    /// conv.
    fn prepared(&self, w: &Weights) -> Result<Arc<Prepared>> {
        let fp = weights_fingerprint(w);
        if let Ok(guard) = self.prepared.read() {
            if let Some(p) = guard.as_ref() {
                if p.fingerprint == fp {
                    return Ok(p.clone());
                }
            }
        }
        let mut guard = self
            .prepared
            .write()
            .map_err(|_| anyhow::anyhow!("{}: prepared-plan lock poisoned", self.name))?;
        if let Some(p) = guard.as_ref() {
            if p.fingerprint == fp {
                return Ok(p.clone());
            }
        }
        let t_build = Instant::now();
        let plan = |wt: &Tensor, bias: &Tensor, sx: &dyn Fn(usize) -> f32| -> Result<QPlan> {
            let qw = quantize_weights(wt)?;
            let (c_out, c_in, kk) = (wt.shape[0], wt.shape[1], wt.shape[2]);
            let g: Vec<f32> = qw
                .scales
                .iter()
                .enumerate()
                .map(|(gi, &sw)| sw * sx(gi % c_in))
                .collect();
            Ok(QPlan {
                panel: PackedI8::pack(&qw.data, c_out, c_in, kk, &g, &bias.data),
            })
        };
        let mut enc = Vec::with_capacity(self.depth);
        let mut dec = Vec::with_capacity(self.depth);
        for l in 1..=self.depth {
            let sx = self.enc_sx[l - 1];
            enc.push(plan(
                &w.tensors[self.idx.enc_w[l - 1]],
                &w.tensors[self.idx.enc_b[l - 1]],
                &|_| sx,
            )?);
            let rows = &self.dec_sx[l - 1];
            dec.push(plan(
                &w.tensors[self.idx.dec_w[l - 1]],
                &w.tensors[self.idx.dec_b[l - 1]],
                &|i| rows[i],
            )?);
        }
        let mut up = BTreeMap::new();
        for (&p, &wi) in &self.idx.up_w {
            let wt = &w.tensors[wi];
            let bias = &w.tensors[self.idx.up_b[&p]];
            let sx = self.qs.s_dec[p - 1];
            let qw = quantize_weights(wt)?;
            let (c_out, c_in) = (wt.shape[0], wt.shape[1]);
            let g: Vec<f32> = qw.scales.iter().map(|&sw| sw * sx).collect();
            up.insert(
                p,
                QUpPlan {
                    phases: [
                        PackedI8::pack_tap(&qw.data, c_out, c_in, 2, 0, &g, &bias.data),
                        PackedI8::pack_tap(&qw.data, c_out, c_in, 2, 1, &g, &bias.data),
                    ],
                },
            );
        }
        let head = plan(
            &w.tensors[self.idx.head_w],
            &w.tensors[self.idx.head_b],
            &|_| self.head_sx,
        )?;
        let built = Arc::new(Prepared {
            fingerprint: fp,
            enc,
            dec,
            up,
            head,
        });
        // A rebuild is rare (first use, or a weight re-upload such as a
        // pruning sweep) but expensive — surface it in the health feed
        // via the global hook (no-op when telemetry is not installed).
        let panels = built.enc.len() + built.dec.len() + 2 * built.up.len() + 1;
        let bytes = built
            .enc
            .iter()
            .chain(built.dec.iter())
            .map(|p| p.panel.bytes())
            .sum::<usize>()
            + built
                .up
                .values()
                .map(|u| u.phases[0].bytes() + u.phases[1].bytes())
                .sum::<usize>()
            + built.head.panel.bytes();
        let ns = t_build.elapsed().as_nanos() as u64;
        crate::obs::with_global(|t| t.shared().quant_repack(panels, bytes, ns));
        *guard = Some(built.clone());
        Ok(built)
    }

    /// Validate a step request, then execute it inside this variant's
    /// per-thread [`StepArena`].  Returns whether an output was written
    /// to the sink.
    fn run_step_batch(
        &self,
        phase: usize,
        frames: Option<&[&[f32]]>,
        states: &mut [&mut StateSet],
        dw: &DeviceWeights,
        part: Part,
        sink: &mut OutSink,
    ) -> Result<bool> {
        let bsz = states.len();
        for st in states.iter() {
            if st.tensors.len() != self.specs.len() {
                bail!(
                    "{}: state set holds {} tensors, expected {}",
                    self.name,
                    st.tensors.len(),
                    self.specs.len()
                );
            }
        }
        if let Some(fr) = frames {
            if fr.len() != bsz {
                bail!("{}: {} frames for {} state sets", self.name, fr.len(), bsz);
            }
            for f in fr.iter() {
                if f.len() != self.cfg.feat {
                    bail!(
                        "{}: frame has {} samples, expected {}",
                        self.name,
                        f.len(),
                        self.cfg.feat
                    );
                }
            }
        }
        if bsz == 0 {
            if let OutSink::Batch(outs) = sink {
                outs.clear();
            }
            return Ok(true);
        }
        let hw = self.host(dw)?;
        let plan = self.prepared(hw.weights())?;
        with_arena(self.arena_id, &self.arena_spec, |arena| {
            self.exec_step(phase % self.period, frames, states, &plan, part, arena, sink)
        })
    }

    /// One quantized inference (or one FP part of it) at schedule
    /// position `phase` for a phase-aligned batch of streams — the same
    /// single code path contract as the f32 interpreter: the
    /// single-stream entry points are `B == 1`, so batched and
    /// sequential execution cannot diverge.
    #[allow(clippy::too_many_arguments)]
    fn exec_step(
        &self,
        phase: usize,
        frames: Option<&[&[f32]]>,
        states: &mut [&mut StateSet],
        plan: &Prepared,
        part: Part,
        arena: &mut StepArena,
        sink: &mut OutSink,
    ) -> Result<bool> {
        let bsz = states.len();
        let pp = &self.plans[phase];
        let depth = self.depth;
        let k = self.cfg.kernel;
        let s = self.cfg.shift_pos;
        let delayed = |l: usize| s.map_or(false, |sp| l >= sp);
        let in_part = |l: usize| match part {
            Part::All => true,
            Part::Pre => delayed(l),
            Part::Rest => !delayed(l),
        };

        // ---- encoder ----
        let mut enc_out = arena.take_opts_i32(depth + 1);
        let mut cur: Option<Vec<i32>> = match part {
            Part::Pre => None,
            _ => {
                let fr = frames.with_context(|| format!("{}: step needs frames", self.name))?;
                let mut x0 = arena.take_i32(self.cfg.feat, bsz);
                for (si, f) in fr.iter().enumerate() {
                    for (i, &v) in f.iter().enumerate() {
                        x0[i * bsz + si] = quantize_act(v, self.qs.s_in);
                    }
                }
                Some(x0)
            }
        };
        for l in 1..=depth {
            let ld = &self.dims[l - 1];
            if !pp.enc_tick[l - 1] {
                arena.release_i32(&mut cur);
                continue;
            }
            if s == Some(l) {
                let fifo_slot = self.idx.shift_fifo.unwrap();
                let mut delayed_in = arena.take_i32(ld.enc_ci, bsz);
                if part != Part::Pre {
                    let c = cur
                        .as_ref()
                        .with_context(|| format!("{}: enc{l} missing input", self.name))?;
                    for (si, st) in states.iter_mut().enumerate() {
                        let fifo = &mut st.tensors[fifo_slot];
                        gather_state_col_q(fifo, 0, bsz, si, &mut delayed_in);
                        push_fifo_col_q(fifo, c, bsz, si);
                    }
                } else {
                    for (si, st) in states.iter().enumerate() {
                        gather_state_col_q(&st.tensors[fifo_slot], 0, bsz, si, &mut delayed_in);
                    }
                }
                arena.release_i32(&mut cur);
                if in_part(l) {
                    cur = Some(delayed_in);
                } else {
                    arena.put_i32(delayed_in);
                }
            }
            if !in_part(l) {
                arena.release_i32(&mut cur);
                continue;
            }
            let c = cur
                .take()
                .with_context(|| format!("{}: enc{l} has no input at phase {phase}", self.name))?;
            let mut xwin = arena.take_i32(ld.enc_ci * k, bsz);
            for (si, st) in states.iter_mut().enumerate() {
                push_window_col_q(&mut st.tensors[self.idx.enc_win[l - 1]], &c, bsz, si, &mut xwin);
            }
            arena.put_i32(c);
            cur = if pp.enc_fire[l - 1] {
                let qp = &plan.enc[l - 1];
                let c_out = qp.panel.c_out;
                let mut pre = arena.take_f32(c_out, bsz);
                gemm_i8(&qp.panel, &xwin, bsz, &mut pre);
                self.macs.fetch_add(
                    (c_out * qp.panel.c_in * qp.panel.k * bsz) as u64,
                    Ordering::Relaxed,
                );
                let lut = &self.luts_enc[l - 1];
                let mut y = arena.take_i32(c_out, bsz);
                for (dst, &p) in y.iter_mut().zip(pre.iter()) {
                    *dst = lut.apply(requant(p, lut.scale));
                }
                arena.put_f32(pre);
                let mut keep = arena.take_i32(c_out, bsz);
                keep.copy_from_slice(&y);
                enc_out[l] = Some(keep);
                Some(y)
            } else {
                None
            };
            arena.put_i32(xwin);
        }
        arena.release_i32(&mut cur);

        // ---- decoder ----
        let mut d: Option<Vec<i32>> = None;
        for l in (1..=depth).rev() {
            let ld = &self.dims[l - 1];
            let mut computed_here = false;
            if pp.dec_run[l - 1] {
                if !in_part(l) {
                    arena.release_i32(&mut d);
                } else {
                    let inp: Vec<i32> = if l == depth {
                        let src = enc_out[l]
                            .as_ref()
                            .with_context(|| format!("{}: dec{l} missing input", self.name))?;
                        let mut v = arena.take_i32(ld.dec_ci, bsz);
                        v.copy_from_slice(src);
                        v
                    } else {
                        let mut upper = d.take();
                        if part == Part::Rest && delayed(l + 1) && !self.is_scc[l + 1] {
                            arena.release_i32(&mut upper);
                            let slot = self.idx.fp_handoff.unwrap();
                            let c_h = states[0].tensors[slot].shape[0];
                            let mut h = arena.take_i32(c_h, bsz);
                            for (si, st) in states.iter().enumerate() {
                                gather_state_col_q(&st.tensors[slot], 0, bsz, si, &mut h);
                            }
                            upper = Some(h);
                        }
                        let v = upper
                            .with_context(|| format!("{}: dec{l} missing deep input", self.name))?;
                        let skip = enc_out[l]
                            .as_ref()
                            .with_context(|| format!("{}: dec{l} missing skip", self.name))?;
                        let mut inp = arena.take_i32(ld.dec_ci, bsz);
                        inp[..v.len()].copy_from_slice(&v);
                        inp[v.len()..].copy_from_slice(skip);
                        arena.put_i32(v);
                        inp
                    };
                    debug_assert_eq!(inp.len(), ld.dec_ci * bsz);
                    let mut xwin = arena.take_i32(ld.dec_ci * k, bsz);
                    for (si, st) in states.iter_mut().enumerate() {
                        push_window_col_q(
                            &mut st.tensors[self.idx.dec_win[l - 1]],
                            &inp,
                            bsz,
                            si,
                            &mut xwin,
                        );
                    }
                    arena.put_i32(inp);
                    let qp = &plan.dec[l - 1];
                    let c_out = qp.panel.c_out;
                    let mut pre = arena.take_f32(c_out, bsz);
                    gemm_i8(&qp.panel, &xwin, bsz, &mut pre);
                    self.macs.fetch_add(
                        (c_out * qp.panel.c_in * qp.panel.k * bsz) as u64,
                        Ordering::Relaxed,
                    );
                    arena.put_i32(xwin);
                    let lut = &self.luts_dec[l - 1];
                    let mut y = arena.take_i32(c_out, bsz);
                    for (dst, &p) in y.iter_mut().zip(pre.iter()) {
                        *dst = lut.apply(requant(p, lut.scale));
                    }
                    arena.put_f32(pre);
                    arena.release_i32(&mut d);
                    d = Some(y);
                    computed_here = true;
                }
            }
            // Extrapolation back to the r_in(l) domain (same write/read
            // ownership rules as the f32 interpreter).
            if self.is_scc[l] && pp.enc_tick[l - 1] {
                let cache_slot = self.idx.up_cache[&l];
                let fresh = pp.dec_run[l - 1];
                if fresh && computed_here {
                    let dv = d.as_ref().unwrap();
                    if self.tconv[l] {
                        let qp = &plan.up[&l];
                        let c_up = qp.phases[0].c_out;
                        let s_up = self.qs.s_up[&l];
                        let mut pre = arena.take_f32(c_up, bsz);
                        let mut phq = arena.take_i32(c_up, bsz);
                        for ph in 0..2usize {
                            gemm_i8(&qp.phases[ph], dv, bsz, &mut pre);
                            self.macs.fetch_add(
                                (c_up * qp.phases[ph].c_in * bsz) as u64,
                                Ordering::Relaxed,
                            );
                            for (dst, &p) in phq.iter_mut().zip(pre.iter()) {
                                *dst = requant(p, s_up);
                            }
                            for (si, st) in states.iter_mut().enumerate() {
                                scatter_state_col_q(&mut st.tensors[cache_slot], ph, &phq, bsz, si);
                            }
                        }
                        arena.put_f32(pre);
                        arena.put_i32(phq);
                    } else {
                        for (si, st) in states.iter_mut().enumerate() {
                            scatter_state_col_q(&mut st.tensors[cache_slot], 0, dv, bsz, si);
                        }
                    }
                }
                let reader_delayed = (l >= 2 && delayed(l - 1)) || (l == 1 && s == Some(1));
                let reads_here = part == Part::All
                    || (reader_delayed && part == Part::Pre)
                    || (!reader_delayed && part == Part::Rest);
                arena.release_i32(&mut d);
                d = if reads_here {
                    let col = if self.tconv[l] && !fresh { 1 } else { 0 };
                    let c_c = states[0].tensors[cache_slot].shape[0];
                    let mut v = arena.take_i32(c_c, bsz);
                    for (si, st) in states.iter().enumerate() {
                        gather_state_col_q(&st.tensors[cache_slot], col, bsz, si, &mut v);
                    }
                    Some(v)
                } else {
                    None
                };
            }
            // FP boundary handoff (pre pass writes; rest pass reads above).
            if part == Part::Pre
                && s == Some(l)
                && !self.is_scc[l]
                && pp.dec_run[l - 1]
                && l != 1
            {
                if let Some(dv) = &d {
                    let slot = self.idx.fp_handoff.unwrap();
                    for (si, st) in states.iter_mut().enumerate() {
                        scatter_state_col_q(&mut st.tensors[slot], 0, dv, bsz, si);
                    }
                }
            }
        }

        // ---- head (dequantizing: output frames are f32) ----
        let feat = self.cfg.feat;
        let produced = match part {
            Part::Pre => {
                if s == Some(1) {
                    let dv = d
                        .take()
                        .with_context(|| format!("{}: pre pass lost the head input", self.name))?;
                    let mut out = arena.take_f32(feat, bsz);
                    gemm_i8(&plan.head.panel, &dv, bsz, &mut out);
                    self.macs
                        .fetch_add((feat * plan.head.panel.c_in * bsz) as u64, Ordering::Relaxed);
                    arena.put_i32(dv);
                    let slot = self.idx.fp_handoff.unwrap();
                    for (si, st) in states.iter_mut().enumerate() {
                        scatter_state_col_f(&mut st.tensors[slot], 0, &out, bsz, si);
                    }
                    arena.put_f32(out);
                }
                false
            }
            Part::Rest if s == Some(1) => {
                let slot = self.idx.fp_handoff.unwrap();
                let mut out = arena.take_f32(feat, bsz);
                for (si, st) in states.iter().enumerate() {
                    gather_state_col_f(&st.tensors[slot], 0, bsz, si, &mut out);
                }
                sink.write(&out, bsz, feat);
                arena.put_f32(out);
                true
            }
            _ => {
                let dv = d
                    .take()
                    .with_context(|| format!("{}: no decoder output at phase {phase}", self.name))?;
                let mut out = arena.take_f32(feat, bsz);
                gemm_i8(&plan.head.panel, &dv, bsz, &mut out);
                self.macs
                    .fetch_add((feat * plan.head.panel.c_in * bsz) as u64, Ordering::Relaxed);
                arena.put_i32(dv);
                sink.write(&out, bsz, feat);
                arena.put_f32(out);
                true
            }
        };
        arena.release_i32(&mut d);
        arena.put_opts_i32(enc_out);
        Ok(produced)
    }
}

impl VariantExec for QuantVariant {
    fn init_states(&self) -> StateSet {
        StateSet {
            tensors: self
                .specs
                .iter()
                .map(|s| Tensor::zeros(s.shape.clone()))
                .collect(),
        }
    }

    fn has_fp_split(&self) -> bool {
        // Same rule as the f32 interpreter: a shift at layer 1 that is
        // also an S-CC position has no handoff slot.
        match self.cfg.shift_pos {
            Some(1) => !self.cfg.scc.contains(&1),
            Some(_) => true,
            None => false,
        }
    }

    fn step(
        &self,
        phase: usize,
        frame: &[f32],
        states: &mut StateSet,
        weights: &DeviceWeights,
    ) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.step_into(phase, frame, states, weights, &mut out)?;
        Ok(out)
    }

    fn step_into(
        &self,
        phase: usize,
        frame: &[f32],
        states: &mut StateSet,
        weights: &DeviceWeights,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let frames = [frame];
        let mut sts = [states];
        let mut sink = OutSink::Single(out);
        let produced = self.run_step_batch(
            phase,
            Some(&frames[..]),
            &mut sts[..],
            weights,
            Part::All,
            &mut sink,
        )?;
        if !produced {
            bail!("{}: step produced no output", self.name);
        }
        Ok(())
    }

    fn precompute(
        &self,
        phase: usize,
        states: &mut StateSet,
        weights: &DeviceWeights,
    ) -> Result<()> {
        if !self.has_fp_split() {
            bail!("{}: variant has no FP split", self.name);
        }
        let mut sts = [states];
        let mut sink = OutSink::Discard;
        self.run_step_batch(phase, None, &mut sts[..], weights, Part::Pre, &mut sink)?;
        Ok(())
    }

    fn step_rest(
        &self,
        phase: usize,
        frame: &[f32],
        states: &mut StateSet,
        weights: &DeviceWeights,
    ) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.step_rest_into(phase, frame, states, weights, &mut out)?;
        Ok(out)
    }

    fn step_rest_into(
        &self,
        phase: usize,
        frame: &[f32],
        states: &mut StateSet,
        weights: &DeviceWeights,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        if !self.has_fp_split() {
            bail!("{}: variant has no FP split", self.name);
        }
        let frames = [frame];
        let mut sts = [states];
        let mut sink = OutSink::Single(out);
        let produced = self.run_step_batch(
            phase,
            Some(&frames[..]),
            &mut sts[..],
            weights,
            Part::Rest,
            &mut sink,
        )?;
        if !produced {
            bail!("{}: rest pass produced no output", self.name);
        }
        Ok(())
    }

    fn step_batch(
        &self,
        phase: usize,
        frames: &[&[f32]],
        states: &mut [&mut StateSet],
        weights: &DeviceWeights,
    ) -> Result<Vec<Vec<f32>>> {
        let mut outs = Vec::new();
        self.step_batch_into(phase, frames, states, weights, &mut outs)?;
        Ok(outs)
    }

    fn step_batch_into(
        &self,
        phase: usize,
        frames: &[&[f32]],
        states: &mut [&mut StateSet],
        weights: &DeviceWeights,
        outs: &mut Vec<Vec<f32>>,
    ) -> Result<()> {
        let mut sink = OutSink::Batch(outs);
        let produced =
            self.run_step_batch(phase, Some(frames), states, weights, Part::All, &mut sink)?;
        if !produced {
            bail!("{}: batched step produced no output", self.name);
        }
        Ok(())
    }

    fn step_rest_batch(
        &self,
        phase: usize,
        frames: &[&[f32]],
        states: &mut [&mut StateSet],
        weights: &DeviceWeights,
    ) -> Result<Vec<Vec<f32>>> {
        let mut outs = Vec::new();
        self.step_rest_batch_into(phase, frames, states, weights, &mut outs)?;
        Ok(outs)
    }

    fn step_rest_batch_into(
        &self,
        phase: usize,
        frames: &[&[f32]],
        states: &mut [&mut StateSet],
        weights: &DeviceWeights,
        outs: &mut Vec<Vec<f32>>,
    ) -> Result<()> {
        if !self.has_fp_split() {
            bail!("{}: variant has no FP split", self.name);
        }
        let mut sink = OutSink::Batch(outs);
        let produced =
            self.run_step_batch(phase, Some(frames), states, weights, Part::Rest, &mut sink)?;
        if !produced {
            bail!("{}: batched rest pass produced no output", self.name);
        }
        Ok(())
    }

    fn offline(&self, x: &Tensor, weights: &DeviceWeights) -> Result<Tensor> {
        // The quantized path has no separate offline network: offline is
        // the streaming loop from zeroed states, which keeps quantized
        // offline == quantized streaming an identity by construction.
        if x.shape.len() != 2 || x.shape[0] != self.cfg.feat {
            bail!(
                "{}: offline input shape {:?}, expected [{}, T]",
                self.name,
                x.shape,
                self.cfg.feat
            );
        }
        if x.shape[1] == 0 || x.shape[1] % self.period != 0 {
            bail!(
                "{}: offline T = {} must be a positive multiple of the period {}",
                self.name,
                x.shape[1],
                self.period
            );
        }
        let t = x.shape[1];
        let mut states = self.init_states();
        let mut out = Tensor::zeros(vec![self.cfg.feat, t]);
        let mut frame = vec![0.0f32; self.cfg.feat];
        let mut y = Vec::with_capacity(self.cfg.feat);
        for tt in 0..t {
            for (i, v) in frame.iter_mut().enumerate() {
                *v = x.at2(i, tt);
            }
            self.step_into(tt, &frame, &mut states, weights, &mut y)?;
            for (i, &v) in y.iter().enumerate() {
                out.set2(i, tt, v);
            }
        }
        Ok(out)
    }

    fn executed_macs(&self) -> Option<u64> {
        Some(self.macs.load(Ordering::Relaxed))
    }

    fn reset_executed_macs(&self) {
        self.macs.store(0, Ordering::Relaxed);
    }

    fn arena_id(&self) -> Option<u64> {
        Some(self.arena_id)
    }
}

/// Order-insensitive-enough FNV fingerprint of a weight set: tensor
/// count, per-tensor length, and a strided sample of element bits.
/// Collisions only matter if a *different* upload fingerprints equal,
/// which would silently reuse stale quantized codes — the stride keeps
/// the sample dense enough (≥ 16 probes per tensor) that any real
/// weight change (pruning, retraining) lands on a probed element with
/// overwhelming probability.
fn weights_fingerprint(w: &Weights) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut mix = |h: &mut u64, v: u64| {
        *h ^= v;
        *h = h.wrapping_mul(0x100000001b3);
    };
    mix(&mut h, w.tensors.len() as u64);
    for t in &w.tensors {
        mix(&mut h, t.data.len() as u64);
        if t.data.is_empty() {
            continue;
        }
        let step = (t.data.len() / 16).max(1);
        let mut i = 0;
        while i < t.data.len() {
            mix(&mut h, t.data[i].to_bits() as u64);
            i += step;
        }
        mix(&mut h, t.data[t.data.len() - 1].to_bits() as u64);
    }
    h
}

// ---- column/window movers between f32 state tensors and code panels --------
//
// Per-stream states stay (C, W) f32 tensors *holding integer codes*
// (exact for |code| ≤ 32767), so the StateSet machinery — cloning,
// metrics, migration replay — is shared with the f32 path.

/// Read column `col` of stream `si`'s state tensor into column `si` of a
/// (C, B) code panel.
fn gather_state_col_q(t: &Tensor, col: usize, bsz: usize, si: usize, dst: &mut [i32]) {
    let w = t.shape[1];
    for i in 0..t.shape[0] {
        dst[i * bsz + si] = t.data[i * w + col] as i32;
    }
}

/// Write column `si` of a (C, B) code panel into column `col` of stream
/// `si`'s state tensor.
fn scatter_state_col_q(t: &mut Tensor, col: usize, src: &[i32], bsz: usize, si: usize) {
    let w = t.shape[1];
    for i in 0..t.shape[0] {
        t.data[i * w + col] = src[i * bsz + si] as f32;
    }
}

/// f32 variant of [`gather_state_col_q`] for the layer-1 FP handoff (the
/// one state slot carrying real f32 values).
fn gather_state_col_f(t: &Tensor, col: usize, bsz: usize, si: usize, dst: &mut [f32]) {
    let w = t.shape[1];
    for i in 0..t.shape[0] {
        dst[i * bsz + si] = t.data[i * w + col];
    }
}

/// f32 variant of [`scatter_state_col_q`] for the layer-1 FP handoff.
fn scatter_state_col_f(t: &mut Tensor, col: usize, src: &[f32], bsz: usize, si: usize) {
    let w = t.shape[1];
    for i in 0..t.shape[0] {
        t.data[i * w + col] = src[i * bsz + si];
    }
}

/// STMC window tick for stream `si`, code-panel flavour: write the full
/// (C, K) window into column `si` of the (C·K, B) panel and advance the
/// per-stream window state.
fn push_window_col_q(state: &mut Tensor, cur: &[i32], bsz: usize, si: usize, dst: &mut [i32]) {
    let c = state.shape[0];
    let wlen = state.shape[1]; // K - 1
    let k = wlen + 1;
    for i in 0..c {
        let row = &mut state.data[i * wlen..(i + 1) * wlen];
        for (j, &v) in row.iter().enumerate() {
            dst[(i * k + j) * bsz + si] = v as i32;
        }
        let x = cur[i * bsz + si];
        dst[(i * k + wlen) * bsz + si] = x;
        if wlen > 0 {
            row.copy_within(1.., 0);
            row[wlen - 1] = x as f32;
        }
    }
}

/// FIFO tick for stream `si`, code-panel flavour.
fn push_fifo_col_q(state: &mut Tensor, cur: &[i32], bsz: usize, si: usize) {
    let w = state.shape[1];
    for i in 0..state.shape[0] {
        let row = &mut state.data[i * w..(i + 1) * w];
        row.copy_within(1.., 0);
        row[w - 1] = cur[i * bsz + si] as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::synth;
    use crate::runtime::Dtype;

    fn int8_manifest() -> (Manifest, Weights) {
        let cfg = ModelConfig {
            feat: 4,
            channels: vec![5, 6],
            kernel: 3,
            scc: vec![2],
            shift_pos: None,
            shift: 1,
            extrap: vec!["duplicate".into()],
            interp: None,
        };
        let mut m = synth::manifest(&cfg, "scc2:int8", 32);
        let w = synth::he_weights(&m, 0xFEED);
        m.dtype = Dtype::Int8;
        m.quant = Some(crate::quant::calibrate(&m, &w, 64, 7).unwrap());
        (m, w)
    }

    #[test]
    fn compiles_and_steps() {
        let (m, w) = int8_manifest();
        let qv = QuantVariant::new(&m).unwrap();
        let dw = DeviceWeights::host(w);
        let mut st = qv.init_states();
        let frame = vec![0.25f32, -0.5, 0.125, 0.0];
        for t in 0..8 {
            let out = qv.step(t, &frame, &mut st, &dw).unwrap();
            assert_eq!(out.len(), 4);
            assert!(out.iter().all(|v| v.is_finite()));
        }
        assert!(qv.executed_macs().unwrap() > 0);
        qv.reset_executed_macs();
        assert_eq!(qv.executed_macs(), Some(0));
    }

    #[test]
    fn quant_states_hold_integer_codes() {
        let (m, w) = int8_manifest();
        let qv = QuantVariant::new(&m).unwrap();
        let dw = DeviceWeights::host(w);
        let mut st = qv.init_states();
        for t in 0..6 {
            let frame: Vec<f32> = (0..4).map(|i| ((t + i) as f32 * 0.07).sin() * 0.4).collect();
            qv.step(t, &frame, &mut st, &dw).unwrap();
        }
        for tensor in &st.tensors {
            for &v in &tensor.data {
                assert_eq!(v, v.trunc(), "state holds non-integer code {v}");
                assert!(v.abs() <= 32767.0);
            }
        }
    }

    #[test]
    fn rejects_f32_manifest_and_missing_quant() {
        let cfg = ModelConfig {
            feat: 4,
            channels: vec![5],
            kernel: 3,
            scc: vec![],
            shift_pos: None,
            shift: 1,
            extrap: vec![],
            interp: None,
        };
        let m = synth::manifest(&cfg, "stmc", 32);
        assert!(QuantVariant::new(&m).is_err(), "f32 manifest");
        let mut m2 = m.clone();
        m2.dtype = Dtype::Int8;
        assert!(QuantVariant::new(&m2).is_err(), "no quant params");
    }

    #[test]
    fn prepared_plan_rebuilds_on_weight_change() {
        let (m, w) = int8_manifest();
        let qv = QuantVariant::new(&m).unwrap();
        let p1 = qv.prepared(&w).unwrap();
        let p1b = qv.prepared(&w).unwrap();
        assert!(Arc::ptr_eq(&p1, &p1b), "same weights reuse the plan");
        let mut w2 = w.clone();
        w2.tensors[0].data[0] += 1.0;
        let p2 = qv.prepared(&w2).unwrap();
        assert!(!Arc::ptr_eq(&p1, &p2), "changed weights rebuild the plan");
        assert_ne!(p1.fingerprint, p2.fingerprint);
    }
}
