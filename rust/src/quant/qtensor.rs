//! Packed int8 tensors with per-channel, group-refined symmetric scales —
//! the weight format of the quantized execution path (DESIGN.md §10).
//!
//! A [`QTensor`] stores `data.len() / group` scale groups: each run of
//! `group` consecutive row-major elements shares one f32 scale chosen so
//! the group's max magnitude maps to ±[`Q_W`].  For a conv kernel of
//! shape `(C_out, C_in, K)`:
//!
//! * `group == C_in · K` — classic per-output-channel quantization
//!   ([`quantize_per_channel`]);
//! * `group == K` — per-output-channel scales *refined per input
//!   channel* ([`quantize_weights`], the execution default): one scale
//!   per (out, in) pair, which is what lifts the end-to-end output SNR of
//!   the 7-layer U-Net from ~33 dB (per-channel) above the 40 dB serving
//!   bar (measured in DESIGN.md §10).
//!
//! Quantization is symmetric (no zero points) and deterministic:
//! `q = clamp(round(w / s), -127, 127)` with f32 `round` (half away from
//! zero), mirrored exactly by `python/compile/kernels/ref.py`.

use anyhow::{bail, Result};

use crate::util::tensor::Tensor;

/// Symmetric int8 code range for weights (±127; -128 is never produced).
pub const Q_W: i32 = 127;

/// A packed int8 tensor with one f32 scale per `group` elements.
#[derive(Debug, Clone)]
pub struct QTensor {
    /// Dimension sizes, outermost first (same convention as [`Tensor`]).
    pub shape: Vec<usize>,
    /// int8 codes, flattened row-major.
    pub data: Vec<i8>,
    /// One scale per group, in row-major group order
    /// (`scales[g]` covers `data[g * group .. (g + 1) * group]`).
    pub scales: Vec<f32>,
    /// Elements per scale group (divides `data.len()`).
    pub group: usize,
}

impl QTensor {
    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// The scale applied to the flat element index `i`.
    pub fn scale_of(&self, i: usize) -> f32 {
        self.scales[i / self.group]
    }

    /// Reconstruct the f32 tensor `q · s` this quantization represents.
    pub fn dequantize(&self) -> Tensor {
        let data = self
            .data
            .iter()
            .enumerate()
            .map(|(i, &q)| q as f32 * self.scale_of(i))
            .collect();
        Tensor::new(self.shape.clone(), data)
    }
}

/// Quantize a tensor with one symmetric scale per `group` row-major
/// elements: `s = max|group| / 127` (1.0 for an all-zero group, so
/// dequantization stays exact) and `q = clamp(round(w / s))`.
pub fn quantize_groups(t: &Tensor, group: usize) -> Result<QTensor> {
    if group == 0 || t.data.len() % group != 0 {
        bail!(
            "group {group} does not divide tensor of {} elements",
            t.data.len()
        );
    }
    let n_groups = t.data.len() / group;
    let mut scales = Vec::with_capacity(n_groups);
    let mut data = Vec::with_capacity(t.data.len());
    for g in 0..n_groups {
        let chunk = &t.data[g * group..(g + 1) * group];
        let maxabs = chunk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let s = if maxabs == 0.0 { 1.0 } else { maxabs / Q_W as f32 };
        scales.push(s);
        for &v in chunk {
            let q = (v / s).round().clamp(-(Q_W as f32), Q_W as f32);
            data.push(q as i8);
        }
    }
    Ok(QTensor {
        shape: t.shape.clone(),
        data,
        scales,
        group,
    })
}

/// Per-output-channel symmetric quantization: one scale per slice of the
/// outermost axis (`group = shape[1..].product()`).
pub fn quantize_per_channel(t: &Tensor) -> Result<QTensor> {
    if t.shape.is_empty() {
        bail!("cannot channel-quantize a rank-0 tensor");
    }
    let group: usize = t.shape[1..].iter().product::<usize>().max(1);
    quantize_groups(t, group)
}

/// Quantize a conv kernel `(C_out, C_in, K)` with the execution-default
/// granularity: per-(out, in)-channel groups of `K` taps, so the combine
/// factor of the quantized GEMM is per (out, in) pair.
pub fn quantize_weights(t: &Tensor) -> Result<QTensor> {
    if t.shape.len() != 3 {
        bail!(
            "quantize_weights expects a (C_out, C_in, K) kernel, got {:?}",
            t.shape
        );
    }
    quantize_groups(t, t.shape[2])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_exact_for_grid_values() {
        // values already on the ±127 grid of their group reconstruct exactly
        let t = Tensor::new(vec![2, 2, 2], vec![1.0, -0.5, 0.25, 0.125, 2.0, -2.0, 0.0, 1.0]);
        let q = quantize_groups(&t, 2).unwrap();
        assert_eq!(q.scales.len(), 4);
        let back = q.dequantize();
        for (a, b) in t.data.iter().zip(&back.data) {
            assert!((a - b).abs() <= q.scale_of(0).max(1.0) * 0.5, "{a} vs {b}");
        }
        // max of each group maps to ±127
        assert_eq!(q.data[0], 127);
        assert_eq!(q.data[5], -127);
    }

    #[test]
    fn zero_group_gets_unit_scale() {
        let t = Tensor::zeros(vec![1, 1, 3]);
        let q = quantize_weights(&t).unwrap();
        assert_eq!(q.scales, vec![1.0]);
        assert!(q.data.iter().all(|&v| v == 0));
        assert_eq!(q.dequantize().data, vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn per_channel_groups_span_the_channel() {
        let t = Tensor::new(vec![2, 3, 1], vec![0.1, 0.2, 0.3, 1.0, 2.0, 4.0]);
        let q = quantize_per_channel(&t).unwrap();
        assert_eq!(q.group, 3);
        assert_eq!(q.scales.len(), 2);
        assert!((q.scales[1] - 4.0 / 127.0).abs() < 1e-7);
        assert_eq!(q.data[5], 127);
    }

    #[test]
    fn rejects_bad_group() {
        let t = Tensor::zeros(vec![2, 3]);
        assert!(quantize_groups(&t, 4).is_err());
        assert!(quantize_groups(&t, 0).is_err());
        assert!(quantize_weights(&t).is_err());
    }
}
