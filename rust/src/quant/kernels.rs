//! Fixed-point kernels of the quantized execution path (DESIGN.md §10):
//! s16 activation quantization, the i32-accumulator blocked group-dot
//! GEMM with fused scale-combine + bias, and the interpolated ELU LUT.
//!
//! [`conv_win_batch_q`]/[`tconv_phase_batch_q`] are the *scalar
//! reference* kernels: the production interpreter executes the same math
//! through the packed-panel SIMD substrate
//! ([`crate::kernels::gemm_i8`], DESIGN.md §11), which is bit-identical
//! to these references on every ISA — `rust/tests/properties.rs` and the
//! `benches/kernels.rs` A/B keep both in lockstep.  The golden-vector
//! cross-checks against `python/compile/kernels/ref.py` pin *this* file,
//! and the equivalence properties carry that pin to the SIMD path.
//!
//! Numeric contract (mirrored bit-for-bit by the int8 reference in
//! `python/compile/kernels/ref.py`):
//!
//! * activations are s16 codes (`±`[`Q_ACT`]) under a static per-tensor
//!   scale baked at calibration time;
//! * each conv output channel accumulates one i32 dot per (out, in)
//!   weight-scale group (`K` taps — never more than `K · 127 · 32767`,
//!   so i32 cannot overflow for any supported kernel width), then folds
//!   the groups with f32 combine factors `g(o, i) = s_x(i) · s_w(o, i)`
//!   in fixed input-channel order, adds the f32 bias, and requantizes;
//! * per-stream accumulation order is independent of the batch width, so
//!   batched and sequential execution agree bit-for-bit (the same
//!   argument as the f32 backend's `conv_win_batch`).

use super::qtensor::QTensor;

/// Symmetric s16 code range for activations (±32767).
pub const Q_ACT: i32 = 32767;

/// Quantize one real value to its s16 activation code:
/// `clamp(round(v / scale), -32767, 32767)` with f32 round (half away
/// from zero).
#[inline]
pub fn quantize_act(v: f32, scale: f32) -> i32 {
    let q = (v / scale).round();
    q.clamp(-(Q_ACT as f32), Q_ACT as f32) as i32
}

/// Requantize an f32 pre-activation into the s16 domain of `scale`
/// (same rounding and saturation as [`quantize_act`]).
#[inline]
pub fn requant(pre: f32, scale: f32) -> i32 {
    quantize_act(pre, scale)
}

/// Interpolated ELU lookup table over the s16 negative half-range.
///
/// The layer's pre- and post-activation ranges share one scale `s`
/// (|ELU(x)| ≤ |x|, so the post range never outgrows the pre range);
/// under a shared scale the positive half of ELU is the exact identity
/// and only the negative half needs the table.  The table holds
/// `expm1(-j · 32 · s) / s` rounded to integers at 1025 knots, and
/// `apply` linearly interpolates between knots in pure integer math.
///
/// Error bound (DESIGN.md §10): table rounding ≤ 0.5 LSB, interpolation
/// rounding ≤ 0.5 LSB, curvature ≤ 128 s LSB (`h²/8 · max|f''| / s` with
/// knot spacing `h = 32 s` and `|f''| ≤ 1`) — under 2 LSB of `s` for
/// every calibrated scale in practice (`s` ~ 1e-4).
pub struct EluLut {
    /// `table[j] = round(expm1(-(j · 32) · s) / s)`, `j in 0..=1024`.
    table: Vec<i64>,
    /// The shared pre/post-activation scale the table was built for.
    pub scale: f32,
}

impl EluLut {
    /// Knot spacing in s16 codes (the interpolation segment width).
    pub const SEG: i64 = 32;

    /// Build the table for a layer's shared activation scale.
    pub fn new(scale: f32) -> EluLut {
        let s = scale as f64;
        let table = (0..=1024)
            .map(|j| {
                let x = -((j * 32) as f64) * s;
                (x.exp_m1() / s).round() as i64
            })
            .collect();
        EluLut { table, scale }
    }

    /// ELU on an s16 pre-activation code, returning the s16 post-
    /// activation code under the same scale.  `q` must be saturated
    /// (|q| ≤ [`Q_ACT`]); positive codes pass through exactly.
    #[inline]
    pub fn apply(&self, q: i32) -> i32 {
        if q >= 0 {
            return q;
        }
        debug_assert!(q >= -Q_ACT);
        let u = (-q) as i64;
        let seg = (u >> 5) as usize;
        let r = u & 31;
        let lo = self.table[seg];
        let hi = self.table[seg + 1];
        (lo + (((hi - lo) * r + 16) >> 5)) as i32
    }
}

/// Batched quantized step conv over column-stacked windows.
///
/// `xwin` is the `(C_in · K, B)` panel of s16 activation codes (one
/// flattened window per stream column, same layout as the f32 backend),
/// `qw` the packed int8 kernel with `K`-tap groups
/// ([`crate::quant::qtensor::quantize_weights`]), `g` the per-(out, in)
/// combine factors (input scale × weight scale, row-major `(C_out,
/// C_in)`), and `bias` the f32 per-channel bias, added after the group
/// fold.  Writes f32 pre-activations into `out` (`(C_out, B)`) using the
/// caller's scratch (`acc` i32 and `pre` f32, each `B` long), and
/// returns the multiply-accumulate count.
///
/// The loop is the same register-blocked shape as the f32 backend's
/// `conv_win_batch`: one weight group streams over the whole batch
/// panel, so every weight byte is loaded once per batch instead of once
/// per stream.
// The argument list is the kernel ABI (weights, factors, bias, panel,
// width, two scratch panels, output) — bundling it into a struct would
// only move the eight names one level down.
#[allow(clippy::too_many_arguments)]
pub fn conv_win_batch_q(
    qw: &QTensor,
    g: &[f32],
    bias: &[f32],
    xwin: &[i32],
    bsz: usize,
    acc: &mut [i32],
    pre: &mut [f32],
    out: &mut [f32],
) -> u64 {
    let c_out = qw.shape[0];
    let c_in = qw.shape[1];
    let k = qw.shape[2];
    debug_assert_eq!(xwin.len(), c_in * k * bsz);
    debug_assert_eq!(out.len(), c_out * bsz);
    debug_assert_eq!(g.len(), c_out * c_in);
    debug_assert_eq!(qw.group, k);
    debug_assert!(acc.len() >= bsz && pre.len() >= bsz);
    for o in 0..c_out {
        pre[..bsz].fill(0.0);
        for i in 0..c_in {
            acc[..bsz].fill(0);
            let grp = &qw.data[(o * c_in + i) * k..(o * c_in + i + 1) * k];
            for (j, &wv) in grp.iter().enumerate() {
                let wv = wv as i32;
                let xs = &xwin[(i * k + j) * bsz..(i * k + j + 1) * bsz];
                for (a, &x) in acc[..bsz].iter_mut().zip(xs) {
                    *a += wv * x;
                }
            }
            let gf = g[o * c_in + i];
            for (p, &a) in pre[..bsz].iter_mut().zip(acc[..bsz].iter()) {
                *p += gf * a as f32;
            }
        }
        let b = bias[o];
        for (dst, &p) in out[o * bsz..(o + 1) * bsz].iter_mut().zip(pre[..bsz].iter()) {
            *dst = p + b;
        }
    }
    (c_out * c_in * k * bsz) as u64
}

/// Batched quantized stride-2 transposed-conv phase: the int8 twin of
/// the f32 backend's `tconv_phase_batch`.  `x` is a `(C_in, B)` s16
/// panel, `qw` a `(C_out, C_in, 2)` kernel quantized with 2-tap groups,
/// `ph` selects the output phase.  Writes f32 pre-extrapolation values
/// (bias included) into `out` and returns the MAC count.
#[allow(clippy::too_many_arguments)]
pub fn tconv_phase_batch_q(
    qw: &QTensor,
    g: &[f32],
    bias: &[f32],
    ph: usize,
    x: &[i32],
    bsz: usize,
    pre: &mut [f32],
    out: &mut [f32],
) -> u64 {
    let c_out = qw.shape[0];
    let c_in = qw.shape[1];
    debug_assert_eq!(x.len(), c_in * bsz);
    debug_assert_eq!(qw.group, 2);
    for o in 0..c_out {
        pre[..bsz].fill(0.0);
        for i in 0..c_in {
            let wv = qw.data[(o * c_in + i) * 2 + ph] as i32;
            let gf = g[o * c_in + i];
            let xs = &x[i * bsz..(i + 1) * bsz];
            for (p, &xv) in pre[..bsz].iter_mut().zip(xs) {
                *p += gf * (wv * xv) as f32;
            }
        }
        let b = bias[o];
        for (dst, &p) in out[o * bsz..(o + 1) * bsz].iter_mut().zip(pre[..bsz].iter()) {
            *dst = p + b;
        }
    }
    (c_out * c_in * bsz) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::qtensor::quantize_weights;
    use crate::util::tensor::Tensor;

    #[test]
    fn quantize_act_rounds_and_saturates() {
        assert_eq!(quantize_act(0.0, 0.1), 0);
        assert_eq!(quantize_act(0.26, 0.1), 3); // 2.6 rounds away to 3
        assert_eq!(quantize_act(-0.26, 0.1), -3);
        assert_eq!(quantize_act(1e9, 0.1), Q_ACT);
        assert_eq!(quantize_act(-1e9, 0.1), -Q_ACT);
    }

    #[test]
    fn elu_lut_identity_on_positive_and_bounded_on_negative() {
        let s = 1e-3f32;
        let lut = EluLut::new(s);
        assert_eq!(lut.apply(1234), 1234);
        assert_eq!(lut.apply(0), 0);
        for q in [-1, -7, -100, -1000, -5000, -Q_ACT] {
            let got = lut.apply(q) as f32 * s;
            let want = ((q as f32 * s) as f64).exp_m1() as f32;
            assert!(
                (got - want).abs() <= 2.0 * s,
                "q={q}: {got} vs {want} (s={s})"
            );
            assert!(lut.apply(q) <= 0 && lut.apply(q) >= -Q_ACT);
        }
    }

    #[test]
    fn conv_matches_scalar_reference() {
        // 2 out, 2 in, K=3, batch 2: compare against a plain f32 evaluation
        // of the dequantized weights over the dequantized window.
        let w = Tensor::new(
            vec![2, 2, 3],
            vec![0.5, -0.25, 0.125, 1.0, 0.5, -1.0, 0.2, 0.4, -0.2, 0.3, 0.1, 0.6],
        );
        let qw = quantize_weights(&w).unwrap();
        let s_x = 0.01f32;
        let bias = [0.05f32, -0.05];
        // per-(o,i) combine factors
        let g: Vec<f32> = (0..4).map(|gi| s_x * qw.scales[gi]).collect();
        let bsz = 2;
        // (C_in*K, B) window codes
        let xwin: Vec<i32> = (0..12).map(|i| (i as i32 * 7 - 40) % 50).collect();
        let mut acc = vec![0i32; bsz];
        let mut pre = vec![0.0f32; bsz];
        let mut out = vec![0.0f32; 4];
        let macs = conv_win_batch_q(&qw, &g, &bias, &xwin, bsz, &mut acc, &mut pre, &mut out);
        assert_eq!(macs, 2 * 2 * 3 * 2);
        let wd = qw.dequantize();
        for o in 0..2 {
            for b in 0..bsz {
                let mut want = bias[o];
                for r in 0..6 {
                    want += wd.data[o * 6 + r] * (xwin[r * bsz + b] as f32 * s_x);
                }
                let got = out[o * bsz + b];
                assert!((got - want).abs() < 1e-4, "[{o},{b}] {got} vs {want}");
            }
        }
    }

    #[test]
    fn batched_conv_is_bit_identical_to_b1() {
        let w = Tensor::new(vec![1, 2, 2], vec![0.9, -0.3, 0.7, 0.2]);
        let qw = quantize_weights(&w).unwrap();
        let g: Vec<f32> = qw.scales.iter().map(|s| s * 2e-4).collect();
        let bias = [0.01f32];
        let xwin_b2: Vec<i32> = vec![10, 20, -30, 40, 500, -600, 70, 80];
        let mut out2 = vec![0.0f32; 2];
        let (mut acc, mut pre) = (vec![0i32; 2], vec![0.0f32; 2]);
        conv_win_batch_q(&qw, &g, &bias, &xwin_b2, 2, &mut acc, &mut pre, &mut out2);
        for b in 0..2 {
            let xwin_b1: Vec<i32> = (0..4).map(|r| xwin_b2[r * 2 + b]).collect();
            let mut out1 = vec![0.0f32; 1];
            conv_win_batch_q(&qw, &g, &bias, &xwin_b1, 1, &mut acc, &mut pre, &mut out1);
            assert_eq!(out1[0].to_bits(), out2[b].to_bits(), "stream {b}");
        }
    }
}
