//! SOI × pruning composition (the paper's Fig. 6 claim as a runnable
//! example): magnitude-prune an STMC model and an SOI model to the same
//! sparsity and compare quality at equal *effective* complexity.
//!
//! Runs out of the box on the native backend (synthesized untrained
//! weights when `artifacts/` has not been built; the SI-SNRi column only
//! means something with trained artifacts).
//!
//! Run: `cargo run --release --example prune_compose`

use std::sync::Arc;

use soi::dsp::siggen;
use soi::experiments::eval::{eval_utterance, mean_std, output_to_wave};
use soi::pruning;
use soi::runtime::{synth, CompiledVariant, Runtime, Weights};
use soi::util::rng::Rng;

fn si_snri(
    cv: &CompiledVariant,
    w: &Weights,
    rt: &Runtime,
    n: usize,
    seed: u64,
) -> anyhow::Result<f64> {
    let dw = w.to_device(rt)?;
    let feat = cv.manifest.config.feat;
    let t = cv.manifest.offline_t;
    let mut rng = Rng::new(seed);
    let mut imps = Vec::new();
    for _ in 0..n {
        let (x, noisy, clean) = eval_utterance(&mut rng, feat, t);
        let est = output_to_wave(&cv.offline(&x, &dw)?);
        let ns = est.len();
        imps.push(soi::dsp::metrics::si_snr_improvement(
            &noisy[..ns],
            &est[..ns],
            &clean[..ns],
        ));
    }
    Ok(mean_std(&imps).0)
}

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::cpu()?);
    let artifacts = std::path::Path::new("artifacts");
    println!(
        "{:<8} {:>9} {:>12} {:>14} {:>12}",
        "model", "pruned%", "SI-SNRi dB", "eff MMAC/s", "dense MMAC/s"
    );
    for name in ["stmc", "scc1"] {
        let (cv, synthesized) = synth::load_or_synth(rt.clone(), artifacts, name, 42)?;
        if synthesized {
            eprintln!("note: artifacts/{name} missing — synthesized untrained weights");
        }
        let fps = siggen::FS / cv.manifest.config.feat as f64;
        let dense = cv.manifest.macs_per_frame * fps / 1e6;
        let mut w = cv.weights.clone();
        let chunk = w.total_params() / 10;
        for step in 0..=4 {
            if step > 0 {
                pruning::prune_global_magnitude(&mut w, chunk);
            }
            let snr = si_snri(&cv, &w, &rt, 4, 42)?;
            println!(
                "{:<8} {:>9.1} {:>12.2} {:>14.1} {:>12.1}",
                name,
                100.0 * pruning::sparsity(&w),
                snr,
                pruning::effective_macs(dense, &w),
                dense,
            );
        }
    }
    println!("\nAt matched effective MMAC/s, the SOI row (scc1) keeps more quality than");
    println!("pruning STMC down to the same budget — and needs no sparse kernels.");
    Ok(())
}
