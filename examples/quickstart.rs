//! Quickstart: load two SOI variants (pure STMC and S-CC 5), stream one
//! synthetic noisy utterance through each, and compare quality vs
//! computational cost — the paper's core trade in ~60 lines.
//!
//! Runs out of the box on the native backend: when `artifacts/` has not
//! been built, the variants are synthesized with untrained weights
//! (latency + complexity columns are meaningful; SI-SNRi is only
//! meaningful with trained artifacts from `make artifacts`).
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::Arc;

use soi::coordinator::StreamSession;
use soi::dsp::{frames, metrics, siggen};
use soi::runtime::{synth, Runtime};
use soi::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::cpu()?);
    println!(
        "backend: {} ({} device(s))",
        rt.platform(),
        rt.device_count()
    );

    // One synthetic noisy utterance (2 s @ 16 kHz).
    let mut rng = Rng::new(7);
    let feat = 16;
    let (noisy, clean) = siggen::denoise_pair(&mut rng, feat * 2000, siggen::FS);
    let (cols, _) = frames(&noisy, feat);

    let artifacts = std::path::Path::new("artifacts");
    let mut any_synth = false;
    for name in ["stmc", "scc5"] {
        let (cv, synthesized) = synth::load_or_synth(rt.clone(), artifacts, name, 7)?;
        any_synth |= synthesized;
        let cv = Arc::new(cv);
        let dw = Arc::new(cv.device_weights()?);
        let mut sess = StreamSession::new(0, cv, dw);

        // Single-frame online inference, exactly like a live audio device.
        let mut est = Vec::with_capacity(noisy.len());
        for col in &cols {
            est.extend(sess.on_frame(col)?);
        }
        let n = est.len();
        println!(
            "{name:<6} SI-SNRi {:+.2} dB | retain {:>5.1}% of STMC MACs | mean step {:>8.1} µs{}",
            metrics::si_snr_improvement(&noisy[..n], &est, &clean[..n]),
            sess.metrics.retain_pct(),
            sess.metrics.arrival_latency.mean() / 1e3,
            if synthesized { "  [untrained]" } else { "" },
        );
    }
    println!("\nS-CC 5 runs its deep layers at half rate (scattered inference),");
    println!("trading a fraction of a dB for ~35% fewer MACs — Table 1's trade.");
    if any_synth {
        println!("(untrained synthesized weights: read the retain% and latency");
        println!(" columns; run `make artifacts` for meaningful SI-SNRi.)");
    }
    Ok(())
}
