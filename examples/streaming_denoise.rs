//! End-to-end serving driver (DESIGN.md §7/E2E): serve many concurrent
//! synthetic-speech streams through the full stack — rust coordinator →
//! inference backend → SOI U-Net — and report quality, latency
//! percentiles and throughput for STMC vs SOI variants.
//!
//! Runs out of the box on the native backend (synthesized untrained
//! weights when `artifacts/` has not been built; latency/throughput and
//! retain% are real measurements either way, SI-SNRi needs trained
//! artifacts).
//!
//! Run: `cargo run --release --example streaming_denoise -- [streams] [frames]`

use std::sync::Arc;

use soi::coordinator::Server;
use soi::dsp::{frames, metrics, siggen};
use soi::experiments::eval::mean_std;
use soi::runtime::{synth, Runtime};
use soi::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_streams: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(8);
    let n_frames: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(750);
    let workers = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(4)
        .min(8);

    let rt = Arc::new(Runtime::cpu()?);
    let feat = 16;
    let fps = siggen::FS / feat as f64;

    // Shared synthetic workload: n_streams utterances.
    let mut rng = Rng::new(1234);
    let mut streams = Vec::new();
    let mut cleans = Vec::new();
    let mut noisys = Vec::new();
    for _ in 0..n_streams {
        let (noisy, clean) = siggen::denoise_pair(&mut rng, feat * n_frames, siggen::FS);
        let (cols, _) = frames(&noisy, feat);
        streams.push(cols);
        cleans.push(clean);
        noisys.push(noisy);
    }
    println!(
        "E2E serving [{} backend]: {n_streams} streams x {n_frames} frames ({:.1} s audio each), {workers} workers\n",
        rt.platform(),
        n_frames as f64 / fps
    );
    println!(
        "{:<8} {:>9} {:>9} {:>9} {:>8} {:>10} {:>9} {:>8}",
        "variant", "SI-SNRi", "p50 µs", "p99 µs", "retain%", "frames/s", "xRT", "hidden%"
    );

    let artifacts = std::path::Path::new("artifacts");
    for name in ["stmc", "scc2", "scc5", "scc2_5", "sscc5"] {
        let (cv, _) = synth::load_or_synth(rt.clone(), artifacts, name, 1234)?;
        let server = Server::new(Arc::new(cv), workers);
        let report = server.run(&streams)?;

        let mut imps = Vec::new();
        for (sid, outs) in &report.outputs {
            let est: Vec<f32> = outs.iter().flatten().copied().collect();
            let n = est.len();
            imps.push(metrics::si_snr_improvement(
                &noisys[*sid as usize][..n],
                &est,
                &cleans[*sid as usize][..n],
            ));
        }
        let (snr, _) = mean_std(&imps);
        println!(
            "{:<8} {:>9.2} {:>9.1} {:>9.1} {:>8.1} {:>10.0} {:>9.1} {:>8.1}",
            name,
            snr,
            report.metrics.arrival_latency.p50() as f64 / 1e3,
            report.metrics.arrival_latency.p99() as f64 / 1e3,
            report.metrics.retain_pct(),
            report.throughput_fps(),
            report.throughput_fps() / fps,
            100.0 * report.metrics.hidden_fraction(),
        );
    }
    println!("\nSOI rows must keep ~STMC quality at materially lower retain% and");
    println!("higher throughput; the FP row (sscc5) additionally hides work in idle gaps.");
    Ok(())
}
