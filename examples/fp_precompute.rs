//! Fully-predictive SOI in action: the same SS-CC variant served twice —
//! once with the coordinator's idle-gap precompute enabled and once
//! without — showing the paper's FP latency claim: most of each inference
//! can run *before* the frame arrives.
//!
//! Runs out of the box on the native backend (synthesized untrained
//! weights when `artifacts/` has not been built — timing and hidden% are
//! real measurements either way).
//!
//! Run: `cargo run --release --example fp_precompute`

use std::sync::Arc;

use soi::coordinator::StreamSession;
use soi::dsp::{frames, siggen};
use soi::runtime::{synth, Runtime};
use soi::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::cpu()?);
    let feat = 16;
    let mut rng = Rng::new(99);
    let (noisy, _) = siggen::denoise_pair(&mut rng, feat * 1500, siggen::FS);
    let (cols, _) = frames(&noisy, feat);

    let artifacts = std::path::Path::new("artifacts");
    println!("variant   idle-precompute   on-arrival p50   on-arrival p99   hidden%  precomp%(analytic)");
    for name in ["sscc2", "sscc5", "sscc7", "fp1_3"] {
        for use_idle in [false, true] {
            let (cv, _) = synth::load_or_synth(rt.clone(), artifacts, name, 99)?;
            let cv = Arc::new(cv);
            let precomp = 100.0 * cv.manifest.precomputed_fraction;
            let dw = Arc::new(cv.device_weights()?);
            let mut sess = StreamSession::new(0, cv, dw);
            for col in &cols {
                if use_idle {
                    // the gap between frames: run the FP delayed region now
                    sess.idle()?;
                }
                sess.on_frame(col)?;
            }
            println!(
                "{:<9} {:<17} {:>12.1} µs {:>13.1} µs {:>8.1} {:>9.1}",
                name,
                if use_idle { "on" } else { "off" },
                sess.metrics.arrival_latency.p50() as f64 / 1e3,
                sess.metrics.arrival_latency.p99() as f64 / 1e3,
                100.0 * sess.metrics.hidden_fraction(),
                precomp,
            );
        }
    }
    println!("\nWith idle precompute ON, the on-arrival latency drops because the");
    println!("delayed region (the paper's 'Precomputed %' of the network) already ran.");
    Ok(())
}
