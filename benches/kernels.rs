//! Microkernel A/B bench (DESIGN.md §11): for each kernel family ×
//! dtype × batch width, times three legs of the same conv —
//!
//! * `reference`     — the unpacked scalar loop (the pre-panel
//!   interpreters' exact accumulation order; for int8 this is
//!   `soi::quant::kernels`, the golden-vector-pinned reference),
//! * `packed_scalar` — the packed-panel kernel forced onto the scalar
//!   ISA (isolates the layout win),
//! * `packed_simd`   — the packed-panel kernel on the runtime-dispatched
//!   ISA (adds the vector win; equals `packed_scalar` on machines
//!   without SIMD).
//!
//! Before timing, the legs are cross-checked: `packed_scalar` must match
//! `reference` bit-for-bit (both dtypes), and `packed_simd` must be
//! bit-identical for int8 / within the §11 ULP envelope for f32 — so CI's
//! smoke run doubles as an equivalence gate on real shapes.
//!
//! Emits one JSON line per row and rewrites `BENCH_kernels.json` at the
//! workspace root on full runs.
//!
//! Run: `cargo bench --bench kernels`
//! Smoke: `cargo bench --bench kernels -- --smoke` (seconds, no rewrite;
//! CI runs this with `RUSTFLAGS=-Ctarget-cpu=native`).

use std::time::Duration;

use soi::kernels::{
    active_isa, gemm_f32, gemm_f32_on, gemm_i8, gemm_i8_on, Isa, PackedF32, PackedI8,
};
use soi::quant::kernels::{conv_win_batch_q, tconv_phase_batch_q};
use soi::quant::quantize_weights;
use soi::util::bench::{bench_config, black_box};
use soi::util::json::Json;
use soi::util::rng::Rng;
use soi::util::tensor::Tensor;

/// One benched shape: a conv family of the 7-layer U-Net.
struct Family {
    name: &'static str,
    c_out: usize,
    c_in: usize,
    k: usize,
    /// Transposed-conv families bench one output phase (n = c_in).
    tconv: bool,
}

const FAMILIES: [Family; 3] = [
    Family { name: "conv", c_out: 32, c_in: 32, k: 3, tconv: false },
    Family { name: "head", c_out: 16, c_in: 32, k: 1, tconv: false },
    Family { name: "tconv", c_out: 32, c_in: 32, k: 2, tconv: true },
];

/// Unpacked scalar f32 conv — the pre-panel interpreter's exact order.
#[allow(clippy::too_many_arguments)]
fn reference_f32(
    w: &[f32],
    c_out: usize,
    n: usize,
    bias: &[f32],
    x: &[f32],
    bsz: usize,
    out: &mut [f32],
) {
    for o in 0..c_out {
        for b in 0..bsz {
            let mut acc = bias[o];
            for j in 0..n {
                acc += w[o * n + j] * x[j * bsz + b];
            }
            out[o * bsz + b] = acc;
        }
    }
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).fold(0.0f32, |m, (x, y)| m.max((x - y).abs()))
}

#[allow(clippy::too_many_arguments)]
fn row(
    fam: &Family,
    dtype: &str,
    leg: &str,
    isa: &str,
    bsz: usize,
    mean_ns: f64,
    p50_ns: f64,
    macs: usize,
) -> Json {
    Json::obj(vec![
        ("bench", Json::Str("kernels".into())),
        ("family", Json::Str(fam.name.into())),
        ("dtype", Json::Str(dtype.into())),
        ("impl", Json::Str(leg.into())),
        ("isa", Json::Str(isa.into())),
        ("c_out", Json::Num(fam.c_out as f64)),
        ("c_in", Json::Num(fam.c_in as f64)),
        ("k", Json::Num(fam.k as f64)),
        ("batch", Json::Num(bsz as f64)),
        ("mean_ns", Json::Num(mean_ns)),
        ("p50_ns", Json::Num(p50_ns)),
        ("ns_per_mac", Json::Num(mean_ns / macs as f64)),
        ("gmacs_per_s", Json::Num(macs as f64 / mean_ns)),
    ])
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke" || a == "smoke");
    let batches: &[usize] = if smoke { &[1, 8] } else { &[1, 4, 16] };
    let (warm, min_t, min_i) = if smoke {
        (Duration::from_millis(10), Duration::from_millis(40), 5)
    } else {
        (Duration::from_millis(100), Duration::from_millis(400), 20)
    };
    let isa = active_isa();
    println!(
        "# kernels — scalar vs packed-panel vs SIMD microkernel A/B [isa {}]{}",
        isa.name(),
        if smoke { " [smoke]" } else { "" }
    );

    let mut rng = Rng::new(0x51_AD);
    let mut rows: Vec<Json> = Vec::new();
    for fam in &FAMILIES {
        // reduction length the streaming step sees for this family
        let n = if fam.tconv { fam.c_in } else { fam.c_in * fam.k };
        let wt = Tensor::new(
            vec![fam.c_out, fam.c_in, fam.k],
            (0..fam.c_out * fam.c_in * fam.k)
                .map(|_| rng.normal() as f32 * 0.3)
                .collect(),
        );
        let bias: Vec<f32> = (0..fam.c_out).map(|_| rng.normal() as f32 * 0.05).collect();
        // flat (c_out, n) weight view of the benched op
        let wflat: Vec<f32> = if fam.tconv {
            (0..fam.c_out * fam.c_in)
                .map(|oi| wt.data[oi * fam.k]) // phase 0 taps
                .collect()
        } else {
            wt.data.clone()
        };
        let pf = if fam.tconv {
            PackedF32::from_conv_tap(&wt, 0).unwrap()
        } else {
            PackedF32::from_conv(&wt).unwrap()
        };
        let qw = quantize_weights(&wt).unwrap();
        let g: Vec<f32> = qw
            .scales
            .iter()
            .enumerate()
            .map(|(gi, &sw)| sw * 2e-4 * (1.0 + (gi % 5) as f32 * 0.1))
            .collect();
        let pq = if fam.tconv {
            PackedI8::pack_tap(&qw.data, fam.c_out, fam.c_in, fam.k, 0, &g, &bias)
        } else {
            PackedI8::pack(&qw.data, fam.c_out, fam.c_in, fam.k, &g, &bias)
        };

        for &bsz in batches {
            let macs = fam.c_out * n * bsz;
            let xf: Vec<f32> = (0..n * bsz).map(|_| rng.normal() as f32 * 0.5).collect();
            let xq: Vec<i32> = (0..n * bsz).map(|_| (rng.normal() * 9000.0) as i32).collect();
            let mut out = vec![0.0f32; fam.c_out * bsz];
            let mut want = vec![0.0f32; fam.c_out * bsz];

            // ---- equivalence gate (cheap; runs in smoke too) ----
            reference_f32(&wflat, fam.c_out, n, &bias, &xf, bsz, &mut want);
            gemm_f32_on(Isa::Scalar, &pf, &bias, &xf, bsz, &mut out, false);
            assert!(
                out.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                "{}: packed_scalar f32 != reference",
                fam.name
            );
            gemm_f32(&pf, &bias, &xf, bsz, &mut out, false);
            let tol = 1e-5 * (1.0 + n as f32);
            assert!(
                max_abs_diff(&out, &want) <= tol,
                "{}: packed_simd f32 beyond ULP envelope",
                fam.name
            );
            let (mut acc, mut pre) = (vec![0i32; bsz], vec![0.0f32; bsz]);
            if fam.tconv {
                tconv_phase_batch_q(&qw, &g, &bias, 0, &xq, bsz, &mut pre, &mut want);
            } else {
                conv_win_batch_q(&qw, &g, &bias, &xq, bsz, &mut acc, &mut pre, &mut want);
            }
            gemm_i8(&pq, &xq, bsz, &mut out);
            assert!(
                out.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                "{}: packed int8 != reference (must be bit-identical)",
                fam.name
            );

            // ---- timed legs ----
            let legs: [(&str, &str, Box<dyn FnMut() + '_>); 6] = {
                let (w2, b2, x2, p2, q2, g2, xq2) = (&wflat, &bias, &xf, &pf, &pq, &g, &xq);
                let qw2 = &qw;
                [
                    (
                        "f32",
                        "reference",
                        Box::new({
                            let mut o = vec![0.0f32; fam.c_out * bsz];
                            move || {
                                reference_f32(w2, fam.c_out, n, b2, x2, bsz, &mut o);
                                black_box(&o);
                            }
                        }),
                    ),
                    (
                        "f32",
                        "packed_scalar",
                        Box::new({
                            let mut o = vec![0.0f32; fam.c_out * bsz];
                            move || {
                                gemm_f32_on(Isa::Scalar, p2, b2, x2, bsz, &mut o, false);
                                black_box(&o);
                            }
                        }),
                    ),
                    (
                        "f32",
                        "packed_simd",
                        Box::new({
                            let mut o = vec![0.0f32; fam.c_out * bsz];
                            move || {
                                gemm_f32(p2, b2, x2, bsz, &mut o, false);
                                black_box(&o);
                            }
                        }),
                    ),
                    (
                        "int8",
                        "reference",
                        Box::new({
                            let mut o = vec![0.0f32; fam.c_out * bsz];
                            let (mut a, mut p) = (vec![0i32; bsz], vec![0.0f32; bsz]);
                            let tc = fam.tconv;
                            move || {
                                if tc {
                                    tconv_phase_batch_q(qw2, g2, b2, 0, xq2, bsz, &mut p, &mut o);
                                } else {
                                    conv_win_batch_q(qw2, g2, b2, xq2, bsz, &mut a, &mut p, &mut o);
                                }
                                black_box(&o);
                            }
                        }),
                    ),
                    (
                        "int8",
                        "packed_scalar",
                        Box::new({
                            let mut o = vec![0.0f32; fam.c_out * bsz];
                            move || {
                                gemm_i8_on(Isa::Scalar, q2, xq2, bsz, &mut o);
                                black_box(&o);
                            }
                        }),
                    ),
                    (
                        "int8",
                        "packed_simd",
                        Box::new({
                            let mut o = vec![0.0f32; fam.c_out * bsz];
                            move || {
                                gemm_i8(q2, xq2, bsz, &mut o);
                                black_box(&o);
                            }
                        }),
                    ),
                ]
            };
            for (dtype, leg, mut f) in legs {
                let leg_isa = if leg == "packed_simd" { isa.name() } else { "scalar" };
                let r = bench_config(
                    &format!("{}[{dtype} {leg} B={bsz}]", fam.name),
                    warm,
                    min_t,
                    min_i,
                    &mut f,
                );
                println!("{}  ({:.2} ns/MAC)", r.report(), r.mean_ns / macs as f64);
                let j = row(fam, dtype, leg, leg_isa, bsz, r.mean_ns, r.p50_ns, macs);
                println!("{}", j.to_string());
                rows.push(j);
            }
        }
    }

    if smoke {
        println!("# smoke mode: baseline file left untouched");
        return Ok(());
    }
    let baseline = Json::obj(vec![
        ("bench", Json::Str("kernels".into())),
        ("isa", Json::Str(isa.name().into())),
        ("rows", Json::Arr(rows)),
    ]);
    // cargo runs bench binaries with cwd at the package root (rust/);
    // the committed baseline lives one level up at the workspace root
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_kernels.json");
    std::fs::write(&path, baseline.to_string_pretty())?;
    println!("# wrote {}", path.display());
    Ok(())
}
