//! Micro-benchmarks of the in-repo substrates: resamplers, synthetic
//! signal generators, SI-SNR, JSON parsing, complexity engine, pruning —
//! guards against the coordinator's support code becoming the bottleneck
//! (EXPERIMENTS.md §Perf budget: L3 support < 5% of frame budget).
//!
//! Run: `cargo bench --bench substrates`

use soi::complexity::unet;
use soi::dsp::{metrics, resample, siggen};
use soi::util::bench::{bench, black_box};
use soi::util::rng::Rng;

fn main() {
    println!("# substrates");
    let mut rng = Rng::new(1);
    let wave = siggen::speech(&mut rng, 16_000, siggen::FS);

    for m in resample::Method::ALL {
        let r = bench(&format!("resample roundtrip 1s [{}]", m.name()), || {
            black_box(resample::roundtrip(&wave, m));
        });
        println!("{}", r.report());
    }

    let est = wave.clone();
    let r = bench("si_snr 1s", || {
        black_box(metrics::si_snr(&est, &wave));
    });
    println!("{}", r.report());

    let r = bench("siggen speech 1s", || {
        let mut rng = Rng::new(2);
        black_box(siggen::speech(&mut rng, 16_000, siggen::FS));
    });
    println!("{}", r.report());

    let cfg = unet::default_config(vec![2, 5], Some(5));
    let r = bench("complexity network build+sum", || {
        let n = unet::network(&cfg, 256, 1000.0);
        black_box(n.soi_macs_per_frame());
    });
    println!("{}", r.report());

    let manifest = std::fs::read_to_string("artifacts/stmc/manifest.json").ok();
    if let Some(text) = manifest {
        let r = bench("json parse manifest", || {
            black_box(soi::util::json::parse(&text).unwrap());
        });
        println!("{}", r.report());
    }

    let mut rng = Rng::new(3);
    let weights = soi::runtime::Weights {
        tensors: vec![soi::util::tensor::Tensor::new(
            vec![32_000],
            (0..32_000).map(|_| rng.normal() as f32).collect(),
        )],
    };
    let r = bench("prune 1k of 32k weights", || {
        let mut w = weights.clone();
        black_box(soi::pruning::prune_global_magnitude(&mut w, 1000));
    });
    println!("{}", r.report());
}
