//! Multi-stream serving throughput (the end-to-end bench of the
//! coordinator: worker pool + scheduler + PJRT execution).
//!
//! Run: `cargo bench --bench serving`

use std::sync::Arc;

use soi::coordinator::Server;
use soi::dsp::{frames, siggen};
use soi::runtime::{CompiledVariant, Runtime};
use soi::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let root = std::path::Path::new("artifacts");
    if !root.join("stmc").exists() {
        eprintln!("SKIP serving: run `make artifacts` first");
        return Ok(());
    }
    let rt = Arc::new(Runtime::cpu()?);
    let feat = 16;
    let fps = siggen::FS / feat as f64;
    let n_streams = 8;
    let n_frames = 300;
    let mut rng = Rng::new(11);
    let streams: Vec<Vec<Vec<f32>>> = (0..n_streams)
        .map(|_| {
            let (noisy, _) = siggen::denoise_pair(&mut rng, feat * n_frames, siggen::FS);
            frames(&noisy, feat).0
        })
        .collect();

    println!("# serving — {n_streams} streams x {n_frames} frames");
    for workers in [1usize, 2, 4] {
        for name in ["stmc", "scc2", "sscc5"] {
            if !root.join(name).exists() {
                continue;
            }
            let cv = Arc::new(CompiledVariant::load(rt.clone(), &root.join(name))?);
            let server = Server::new(cv, workers);
            let report = server.run(&streams)?;
            println!(
                "serve[{name} w={workers}]  {:>9.0} frames/s  {:>6.1}x realtime  p99 {:>9}  retain {:>5.1}%",
                report.throughput_fps(),
                report.throughput_fps() / fps,
                soi::util::bench::fmt_ns(report.metrics.arrival_latency.p99() as f64),
                report.metrics.retain_pct(),
            );
        }
    }
    Ok(())
}
