//! Multi-stream serving throughput (the end-to-end bench of the
//! coordinator: worker pool + scheduler + backend execution).
//!
//! Runs out of the box on the native backend (synthesized untrained
//! weights when `artifacts/` has not been built — throughput and latency
//! are real).  Emits one JSON line per (variant, workers) pair for
//! cross-PR comparison.
//!
//! Run: `cargo bench --bench serving`

use std::sync::Arc;

use soi::coordinator::Server;
use soi::dsp::{frames, siggen};
use soi::runtime::{synth, Runtime};
use soi::util::json::Json;
use soi::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let root = std::path::Path::new("artifacts");
    let rt = Arc::new(Runtime::cpu()?);
    let feat = 16;
    let fps = siggen::FS / feat as f64;
    let n_streams = 8;
    let n_frames = 300;
    let mut rng = Rng::new(11);
    let streams: Vec<Vec<Vec<f32>>> = (0..n_streams)
        .map(|_| {
            let (noisy, _) = siggen::denoise_pair(&mut rng, feat * n_frames, siggen::FS);
            frames(&noisy, feat).0
        })
        .collect();

    println!(
        "# serving — {n_streams} streams x {n_frames} frames [{} backend]",
        rt.platform()
    );
    for workers in [1usize, 2, 4] {
        for name in ["stmc", "scc2", "sscc5"] {
            let (cv, _) = synth::load_or_synth(rt.clone(), root, name, 11)?;
            let server = Server::new(Arc::new(cv), workers);
            let report = server.run(&streams)?;
            println!(
                "serve[{name} w={workers}]  {:>9.0} frames/s  {:>6.1}x realtime  p99 {:>9}  retain {:>5.1}%",
                report.throughput_fps(),
                report.throughput_fps() / fps,
                soi::util::bench::fmt_ns(report.metrics.arrival_latency.p99() as f64),
                report.metrics.retain_pct(),
            );
            println!(
                "{}",
                Json::obj(vec![
                    ("bench", Json::Str("serving".into())),
                    ("variant", Json::Str(name.into())),
                    ("workers", Json::Num(workers as f64)),
                    ("backend", Json::Str(rt.platform())),
                    ("frames_per_s", Json::Num(report.throughput_fps())),
                    ("p99_ns", Json::Num(report.metrics.arrival_latency.p99() as f64)),
                    ("retain_pct", Json::Num(report.metrics.retain_pct())),
                ])
                .to_string()
            );
        }
    }
    Ok(())
}
