//! Multi-stream serving throughput (the end-to-end bench of the
//! coordinator: worker pool + phase-aligned batching + scheduler +
//! backend execution).
//!
//! Sweeps batching {off, on} × worker count × stream count × variant
//! family, runs out of the box on the native backend (synthesized
//! untrained weights when `artifacts/` has not been built — throughput
//! and latency are real), then drives the adaptive serving controller
//! (DESIGN.md §9) through a paced load spike: calm traffic → a flooded
//! middle third → calm again, adaptive off vs on over the
//! stmc → scc2 → sscc5 ladder.  The adaptive rows record migrations,
//! per-variant frame counts, whether p99 stayed within the controller
//! target, and whether every stream recovered to rung 0 (STMC) by the
//! end.  Emits one JSON line per configuration for cross-PR comparison
//! and rewrites `BENCH_serving.json` at the workspace root — the
//! committed perf baseline future PRs diff against.
//!
//! Run: `cargo bench --bench serving`
//! Smoke: `cargo bench --bench serving -- --smoke` — a tiny sweep
//! (seconds, not minutes) that exercises every code path but leaves the
//! committed `BENCH_serving.json` baseline untouched; CI runs this so
//! the bench can never rot uncompiled.

use std::sync::Arc;

use soi::coordinator::{AdaptivePolicy, Server};
use soi::dsp::{frames, siggen};
use soi::runtime::{synth, CompiledVariant, Runtime, VariantLadder};
use soi::util::json::Json;
use soi::util::rng::Rng;

// Adaptive spike: calm rounds are paced (dispatch gap per round), the
// middle third floods the queue.
const ADAPTIVE_LADDER: [&str; 3] = ["stmc", "scc2", "sscc5"];
const ADAPTIVE_TARGET_US: u64 = 3_000;
const CALM_GAP_US: u64 = 700;

/// Sweep sizes: the full committed-baseline sweep, or the CI smoke run.
struct Sweep {
    variants: Vec<&'static str>,
    workers: Vec<usize>,
    streams: Vec<usize>,
    n_frames: usize,
    adaptive_streams: usize,
    adaptive_workers: usize,
    adaptive_frames: usize,
    spike: std::ops::Range<usize>,
    smoke: bool,
}

impl Sweep {
    fn new(smoke: bool) -> Sweep {
        if smoke {
            Sweep {
                variants: vec!["scc2"],
                workers: vec![2],
                streams: vec![4],
                n_frames: 48,
                adaptive_streams: 4,
                adaptive_workers: 2,
                adaptive_frames: 96,
                spike: 32..64,
                smoke,
            }
        } else {
            Sweep {
                variants: vec!["stmc", "scc2", "sscc5"],
                workers: vec![1, 4],
                streams: vec![4, 16],
                n_frames: 240,
                adaptive_streams: 8,
                adaptive_workers: 2,
                adaptive_frames: 480,
                spike: 160..320,
                smoke,
            }
        }
    }
}

fn run_once(
    cv: &Arc<CompiledVariant>,
    workers: usize,
    batching: bool,
    streams: &[Vec<Vec<f32>>],
) -> anyhow::Result<soi::coordinator::ServeReport> {
    let mut server = Server::new(cv.clone(), workers);
    server.batching = batching;
    server.run(streams)
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke" || a == "smoke");
    let sweep = Sweep::new(smoke);
    let n_frames = sweep.n_frames;
    let root = std::path::Path::new("artifacts");
    let rt = Arc::new(Runtime::cpu()?);
    let feat = 16;
    let fps = siggen::FS / feat as f64;
    let max_streams = *sweep.streams.iter().max().unwrap();
    let mut rng = Rng::new(11);
    let all_streams: Vec<Vec<Vec<f32>>> = (0..max_streams)
        .map(|_| {
            let (noisy, _) = siggen::denoise_pair(&mut rng, feat * n_frames, siggen::FS);
            frames(&noisy, feat).0
        })
        .collect();

    println!(
        "# serving — up to {max_streams} streams x {n_frames} frames [{} backend]{}",
        rt.platform(),
        if smoke { " [smoke]" } else { "" }
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut speedups: Vec<(String, f64)> = Vec::new();
    for name in sweep.variants.iter().copied() {
        let (cv, _) = synth::load_or_synth(rt.clone(), root, name, 11)?;
        let cv = Arc::new(cv);
        // (workers, streams) -> sequential fps, for the speedup summary
        let mut seq_fps = std::collections::BTreeMap::new();
        for workers in sweep.workers.iter().copied() {
            for n_streams in sweep.streams.iter().copied() {
                let streams = &all_streams[..n_streams];
                for batching in [false, true] {
                    let report = run_once(&cv, workers, batching, streams)?;
                    let fps_now = report.throughput_fps();
                    println!(
                        "serve[{name} w={workers} s={n_streams} batch={}]  {:>9.0} frames/s  \
                         {:>6.1}x realtime  p99 {:>9}  retain {:>5.1}%  batch \u{3bc} {:>4.1}",
                        if batching { "on" } else { "off" },
                        fps_now,
                        fps_now / fps,
                        soi::util::bench::fmt_ns(report.metrics.arrival_latency.p99() as f64),
                        report.metrics.retain_pct(),
                        report.metrics.mean_batch(),
                    );
                    let row = Json::obj(vec![
                        ("bench", Json::Str("serving".into())),
                        ("variant", Json::Str(name.into())),
                        ("workers", Json::Num(workers as f64)),
                        ("streams", Json::Num(n_streams as f64)),
                        ("batching", Json::Bool(batching)),
                        ("backend", Json::Str(rt.platform())),
                        ("frames_per_s", Json::Num(fps_now)),
                        (
                            "p99_ns",
                            Json::Num(report.metrics.arrival_latency.p99() as f64),
                        ),
                        ("retain_pct", Json::Num(report.metrics.retain_pct())),
                        ("mean_batch", Json::Num(report.metrics.mean_batch())),
                    ]);
                    let line = row.to_string();
                    println!("{line}");
                    rows.push(row);
                    if batching {
                        if let Some(&base) = seq_fps.get(&(workers, n_streams)) {
                            if n_streams == max_streams {
                                let s = fps_now / f64::max(base, 1e-9);
                                speedups.push((format!("{name}/w{workers}"), s));
                            }
                        }
                    } else {
                        seq_fps.insert((workers, n_streams), fps_now);
                    }
                }
            }
        }
    }

    for (k, s) in &speedups {
        println!("speedup[{k} @ {max_streams} streams]  {s:.2}x");
    }

    // ---- adaptive controller under a load spike (DESIGN.md §9) ----
    let mut lvars = Vec::with_capacity(ADAPTIVE_LADDER.len());
    for name in ADAPTIVE_LADDER {
        let (cv, _) = synth::load_or_synth(rt.clone(), root, name, 11)?;
        lvars.push(Arc::new(cv));
    }
    let ladder = Arc::new(VariantLadder::new(lvars)?);
    let spike_streams: Vec<Vec<Vec<f32>>> = (0..sweep.adaptive_streams)
        .map(|_| {
            let (noisy, _) =
                siggen::denoise_pair(&mut rng, feat * sweep.adaptive_frames, siggen::FS);
            frames(&noisy, feat).0
        })
        .collect();
    let gaps: Vec<u64> = (0..sweep.adaptive_frames)
        .map(|t| if sweep.spike.contains(&t) { 0 } else { CALM_GAP_US })
        .collect();
    for adaptive in [false, true] {
        let mut server = Server::with_ladder(ladder.clone(), sweep.adaptive_workers);
        if adaptive {
            server.adaptive = Some(AdaptivePolicy::with_target_us(ADAPTIVE_TARGET_US));
        }
        let report = server.run_paced(&spike_streams, &gaps)?;
        let p99_us = report.metrics.arrival_latency.p99() as f64 / 1_000.0;
        let recovered = report.final_levels.values().all(|&l| l == 0);
        println!(
            "spike[adaptive={}]  p99 {:>9}  within-target {}  migr {:>3}  \
             recovered-to-{} {}  retain {:>5.1}%",
            if adaptive { "on" } else { "off" },
            soi::util::bench::fmt_ns(report.metrics.arrival_latency.p99() as f64),
            p99_us <= ADAPTIVE_TARGET_US as f64,
            report.metrics.migrations,
            ADAPTIVE_LADDER[0],
            recovered,
            report.metrics.retain_pct(),
        );
        let row = Json::obj(vec![
            ("bench", Json::Str("serving_adaptive".into())),
            (
                "ladder",
                Json::Arr(ADAPTIVE_LADDER.iter().map(|n| Json::Str((*n).into())).collect()),
            ),
            ("adaptive", Json::Bool(adaptive)),
            ("workers", Json::Num(sweep.adaptive_workers as f64)),
            ("streams", Json::Num(sweep.adaptive_streams as f64)),
            ("backend", Json::Str(rt.platform())),
            ("target_p99_us", Json::Num(ADAPTIVE_TARGET_US as f64)),
            ("p99_us", Json::Num(p99_us)),
            ("within_target", Json::Bool(p99_us <= ADAPTIVE_TARGET_US as f64)),
            ("migrations", Json::Num(report.metrics.migrations as f64)),
            ("migration_macs", Json::Num(report.metrics.macs_migration)),
            ("recovered_to_rung0", Json::Bool(recovered)),
            ("retain_pct", Json::Num(report.metrics.retain_pct())),
            (
                "variant_frames",
                Json::Obj(
                    report
                        .metrics
                        .variant_frames
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
                        .collect(),
                ),
            ),
        ]);
        let line = row.to_string();
        println!("{line}");
        rows.push(row);
    }

    if sweep.smoke {
        println!("# smoke mode: baseline file left untouched");
        return Ok(());
    }
    let baseline = Json::obj(vec![
        ("bench", Json::Str("serving".into())),
        ("backend", Json::Str(rt.platform())),
        ("n_frames", Json::Num(n_frames as f64)),
        ("rows", Json::Arr(rows)),
        (
            "speedup_at_max_streams",
            Json::Obj(
                speedups
                    .into_iter()
                    .map(|(k, s)| (k, Json::Num(s)))
                    .collect(),
            ),
        ),
    ]);
    // cargo runs bench binaries with cwd at the package root (rust/);
    // the committed baseline lives one level up at the workspace root
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_serving.json");
    std::fs::write(&path, baseline.to_string_pretty())?;
    println!("# wrote {}", path.display());
    Ok(())
}
