//! f32 vs int8 serving A/B (DESIGN.md §10): per variant family, the same
//! stream set is served twice through the batched worker pool — once on
//! the f32 interpreter, once on the quantized int8/s16 executable — and
//! the bench records the frames/s of both, the speedup, and the
//! quantized output's SNR against the f32 run (same weights, so the f32
//! outputs *are* the reference).
//!
//! Runs out of the box on the native backend (synthesized untrained
//! weights when `artifacts/` has not been built — throughput and SNR vs
//! the f32 twin are both real).  Emits one JSON line per (variant,
//! dtype) configuration and rewrites `BENCH_quant.json` at the workspace
//! root — the committed A/B baseline future PRs diff against.
//!
//! Run: `cargo bench --bench quant`
//! Smoke: `cargo bench --bench quant -- --smoke` — tiny config, seconds
//! not minutes, no baseline rewrite; CI runs this so the bench can never
//! rot uncompiled.

use std::sync::Arc;

use soi::coordinator::Server;
use soi::dsp::{frames, siggen};
use soi::runtime::{synth, Runtime};
use soi::util::json::Json;
use soi::util::rng::Rng;

const VARIANTS: [&str; 3] = ["stmc", "scc2", "sscc5"];

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke" || a == "smoke");
    let (n_streams, n_frames, workers) = if smoke { (4, 48, 2) } else { (16, 240, 4) };
    let root = std::path::Path::new("artifacts");
    let rt = Arc::new(Runtime::cpu()?);
    let feat = 16;
    let fps = siggen::FS / feat as f64;
    let mut rng = Rng::new(23);
    let streams: Vec<Vec<Vec<f32>>> = (0..n_streams)
        .map(|_| {
            let (noisy, _) = siggen::denoise_pair(&mut rng, feat * n_frames, siggen::FS);
            frames(&noisy, feat).0
        })
        .collect();

    println!(
        "# quant — f32 vs int8 A/B, {n_streams} streams x {n_frames} frames, \
         {workers} workers, batched [{} backend]{}",
        rt.platform(),
        if smoke { " [smoke]" } else { "" }
    );
    let mut rows: Vec<Json> = Vec::new();
    for name in VARIANTS {
        let mut f32_fps = 0.0f64;
        let mut f32_out: Vec<Vec<Vec<f32>>> = Vec::new();
        for dtype in ["f32", "int8"] {
            let spec = if dtype == "f32" {
                name.to_string()
            } else {
                format!("{name}:int8")
            };
            let (cv, _) = synth::load_or_synth(rt.clone(), root, &spec, 23)?;
            let server = Server::new(Arc::new(cv), workers);
            let report = server.run(&streams)?;
            let fps_now = report.throughput_fps();
            // int8 fidelity: SNR of every served sample against the f32
            // run of the same streams (identical weights by construction)
            let snr = if dtype == "f32" {
                f32_fps = fps_now;
                f32_out = (0..n_streams as u64)
                    .map(|sid| report.outputs[&sid].clone())
                    .collect();
                f64::NAN
            } else {
                let reference: Vec<f32> = f32_out.iter().flatten().flatten().copied().collect();
                let served: Vec<f32> = (0..n_streams as u64)
                    .flat_map(|sid| report.outputs[&sid].iter().flatten().copied())
                    .collect();
                soi::dsp::metrics::output_snr_db(&reference, &served)
            };
            let speedup = if dtype == "int8" && f32_fps > 0.0 {
                fps_now / f32_fps
            } else {
                1.0
            };
            println!(
                "quant[{name} {dtype}]  {fps_now:>9.0} frames/s  {:>6.1}x realtime  \
                 p99 {:>9}  speedup-vs-f32 {speedup:>5.2}x  snr {}",
                fps_now / fps,
                soi::util::bench::fmt_ns(report.metrics.arrival_latency.p99() as f64),
                if snr.is_nan() { "    -".to_string() } else { format!("{snr:.1} dB") },
            );
            let row = Json::obj(vec![
                ("bench", Json::Str("quant".into())),
                ("variant", Json::Str(name.into())),
                ("dtype", Json::Str(dtype.into())),
                ("workers", Json::Num(workers as f64)),
                ("streams", Json::Num(n_streams as f64)),
                ("backend", Json::Str(rt.platform())),
                ("frames_per_s", Json::Num(fps_now)),
                (
                    "p99_ns",
                    Json::Num(report.metrics.arrival_latency.p99() as f64),
                ),
                ("retain_pct", Json::Num(report.metrics.retain_pct())),
                ("speedup_vs_f32", Json::Num(speedup)),
                (
                    "snr_db",
                    if snr.is_nan() { Json::Null } else { Json::Num(snr) },
                ),
            ]);
            println!("{}", row.to_string());
            rows.push(row);
        }
    }

    if smoke {
        println!("# smoke mode: baseline file left untouched");
        return Ok(());
    }
    let baseline = Json::obj(vec![
        ("bench", Json::Str("quant".into())),
        ("backend", Json::Str(rt.platform())),
        ("n_frames", Json::Num(n_frames as f64)),
        ("rows", Json::Arr(rows)),
    ]);
    // cargo runs bench binaries with cwd at the package root (rust/);
    // the committed baseline lives one level up at the workspace root
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_quant.json");
    std::fs::write(&path, baseline.to_string_pretty())?;
    println!("# wrote {}", path.display());
    Ok(())
}
