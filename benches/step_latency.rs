//! Per-frame step latency across SOI variants (the hot path behind the
//! paper's Table 6 / Fig. 8 timing columns).  criterion is unavailable
//! offline; this uses the in-repo harness (`util::bench`) with
//! `harness = false`.
//!
//! Runs out of the box on the native backend: variants are synthesized
//! (untrained weights — irrelevant for latency) when `artifacts/` has not
//! been built.  Each variant is timed at both execution precisions
//! (DESIGN.md §10): the f32 interpreter and the quantized int8/s16
//! executable, whose JSON rows additionally carry the measured output
//! SNR against the f32 twin.  Besides the human-readable report, each
//! (variant, dtype) pair emits one machine-readable JSON line
//! (`{"bench":"step_latency",...}`) so results are comparable across PRs.
//!
//! Run: `cargo bench --bench step_latency`

use std::sync::Arc;

use soi::dsp::{frames, siggen};
use soi::runtime::{synth, Runtime};
use soi::util::bench::bench;
use soi::util::json::Json;
use soi::util::rng::Rng;

fn json_line(fields: Vec<(&str, Json)>) -> String {
    Json::obj(fields).to_string()
}

fn main() -> anyhow::Result<()> {
    let root = std::path::Path::new("artifacts");
    let rt = Arc::new(Runtime::cpu()?);
    let feat = 16;
    let mut rng = Rng::new(3);
    let (noisy, _) = siggen::denoise_pair(&mut rng, feat * 64, siggen::FS);
    let (cols, _) = frames(&noisy, feat);

    println!(
        "# step_latency — single-stream per-frame inference [{} backend]",
        rt.platform()
    );
    for name in ["stmc", "scc1", "scc2", "scc5", "scc7", "scc2_5", "sscc5"] {
        // f32 reference outputs for the int8 row's SNR measurement
        let mut f32_out: Vec<f32> = Vec::new();
        for dtype in ["f32", "int8"] {
            let spec = if dtype == "f32" {
                name.to_string()
            } else {
                format!("{name}:int8")
            };
            let (cv, _) = synth::load_or_synth(rt.clone(), root, &spec, 3)?;
            let cv = Arc::new(cv);
            let dw = Arc::new(cv.device_weights()?);
            // output fidelity first (fresh session, deterministic)
            let snr = {
                let mut probe = soi::coordinator::StreamSession::new(9, cv.clone(), dw.clone());
                let mut out = Vec::with_capacity(cols.len() * feat);
                for col in &cols {
                    out.extend(probe.on_frame(col)?);
                }
                if dtype == "f32" {
                    f32_out = out;
                    f64::NAN
                } else {
                    soi::dsp::metrics::output_snr_db(&f32_out, &out)
                }
            };
            let mut sess = soi::coordinator::StreamSession::new(0, cv.clone(), dw.clone());
            let mut i = 0usize;
            let r = bench(&format!("step[{spec}]"), || {
                sess.on_frame(&cols[i % cols.len()]).unwrap();
                i += 1;
            });
            println!("{}  ({:.0} frames/s)", r.report(), r.throughput_per_sec());
            println!(
                "{}",
                json_line(vec![
                    // version tag (DESIGN.md appendix A): parsers can
                    // dispatch on it instead of sniffing fields
                    ("schema", Json::Str("soi.step_latency.v2".into())),
                    ("bench", Json::Str("step_latency".into())),
                    ("variant", Json::Str(name.into())),
                    ("dtype", Json::Str(dtype.into())),
                    ("backend", Json::Str(rt.platform())),
                    ("mean_ns", Json::Num(r.mean_ns)),
                    ("p50_ns", Json::Num(r.p50_ns)),
                    ("p95_ns", Json::Num(r.p95_ns)),
                    ("frames_per_s", Json::Num(r.throughput_per_sec())),
                    ("macs_per_frame", Json::Num(cv.manifest.macs_per_frame)),
                    (
                        // efficiency, not just counts: mean per-frame
                        // wall time over the period-average MACs/frame
                        "ns_per_mac",
                        if cv.manifest.macs_per_frame > 0.0 {
                            Json::Num(r.mean_ns / cv.manifest.macs_per_frame)
                        } else {
                            Json::Null
                        },
                    ),
                    (
                        "snr_db",
                        if snr.is_nan() { Json::Null } else { Json::Num(snr) },
                    ),
                ])
            );

            if cv.has_fp_split() {
                let mut sess2 = soi::coordinator::StreamSession::new(1, cv, dw);
                let mut j = 0usize;
                let r2 = bench(&format!("step[{spec}] rest-only (FP overlap)"), || {
                    sess2.idle().unwrap();
                    sess2.on_frame(&cols[j % cols.len()]).unwrap();
                    j += 1;
                });
                println!(
                    "{}  (arrival work only: p50 {})",
                    r2.report(),
                    soi::util::bench::fmt_ns(sess2.metrics.arrival_latency.p50() as f64)
                );
            }
        }
    }
    Ok(())
}
