"""Build-time trainer for the SOI variants (synthetic substitution of the
paper's DNS / TAU training runs — DESIGN.md §5).

The paper trains each U-Net variant for 100 epochs (~14 h on a P40); we fit
tiny-channel variants on the synthetic denoising task for a few hundred
Adam steps — enough to reproduce the *shape* of the quality/complexity
trade (earlier S-CC ⇒ lower SI-SNRi), which is what the experiment harness
asserts.

Everything here is build-time only.  `make artifacts` invokes
:func:`train_variant` through aot.py; weights are cached per variant under
``artifacts/``.

Optimizer: hand-rolled Adam (optax is not available offline).
"""

from __future__ import annotations

import functools
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import data
from .model import Params, UNetConfig, init_params, offline_forward

# ---------------------------------------------------------------------------
# Adam
# ---------------------------------------------------------------------------


def adam_init(params: Params):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in params.items()}, "t": 0}


def clip_by_global_norm(grads, max_norm: float = 1.0):
    norm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads.values()))
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return {k: g * scale for k, g in grads.items()}


def adam_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    grads = clip_by_global_norm(grads)
    t = state["t"] + 1
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in params}
    v = {k: b2 * state["v"][k] + (1 - b2) * grads[k] ** 2 for k in params}
    mhat = {k: m[k] / (1 - b1**t) for k in params}
    vhat = {k: v[k] / (1 - b2**t) for k in params}
    new = {k: params[k] - lr * mhat[k] / (jnp.sqrt(vhat[k]) + eps) for k in params}
    return new, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# Losses / metrics
# ---------------------------------------------------------------------------


def si_snr_jax(est: jnp.ndarray, target: jnp.ndarray, eps: float = 1e-8) -> jnp.ndarray:
    """Scale-invariant SNR (dB) of flattened per-example signals.

    est/target: (B, feat, T) — flattened per example.
    """
    b = est.shape[0]
    e = est.reshape(b, -1)
    t = target.reshape(b, -1)
    e = e - e.mean(axis=1, keepdims=True)
    t = t - t.mean(axis=1, keepdims=True)
    dot = jnp.sum(e * t, axis=1, keepdims=True)
    s = dot * t / (jnp.sum(t * t, axis=1, keepdims=True) + eps)
    noise = e - s
    return 10.0 * jnp.log10(
        (jnp.sum(s * s, axis=1) + eps) / (jnp.sum(noise * noise, axis=1) + eps)
    )


def neg_si_snr_loss(cfg: UNetConfig, params: Params, noisy, clean) -> jnp.ndarray:
    fwd = jax.vmap(lambda x: offline_forward(cfg, params, x))
    est = fwd(noisy)
    return -jnp.mean(si_snr_jax(est, clean))


# ---------------------------------------------------------------------------
# Training loop (speech separation)
# ---------------------------------------------------------------------------


def make_dataset(seed: int, n_train: int, n_eval: int, t_frames: int, feat: int):
    """Fixed pregenerated corpora (the paper uses a fixed 16384-sample set)."""
    rng = np.random.default_rng(seed)
    train = data.denoise_batch(rng, n_train, t_frames, feat)
    evl = data.denoise_batch(np.random.default_rng(seed + 1), n_eval, t_frames, feat)
    return train, evl


def train_variant(
    cfg: UNetConfig,
    steps: int = 500,
    batch: int = 16,
    t_frames: int = 128,
    n_train: int = 160,
    n_eval: int = 24,
    lr: float = 1e-3,
    seed: int = 0,
    log_every: int = 100,
    progress: Callable[[str], None] = print,
) -> Tuple[Params, Dict[str, float]]:
    """Train one variant; returns (params, metrics).

    metrics: si_snri (mean SI-SNR improvement on the eval set, dB),
    si_snr_noisy (input SI-SNR), loss_first/loss_last (the loss curve ends,
    logged to EXPERIMENTS.md).
    """
    (tr_x, tr_y), (ev_x, ev_y) = make_dataset(seed + 100, n_train, n_eval, t_frames, cfg.feat)
    params = init_params(cfg, seed=seed)
    opt = adam_init(params)

    loss_fn = functools.partial(neg_si_snr_loss, cfg)
    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    rng = np.random.default_rng(seed + 7)
    loss_first = loss_last = None
    for step in range(steps):
        idx = rng.integers(0, n_train, size=batch)
        loss, grads = grad_fn(params, jnp.asarray(tr_x[idx]), jnp.asarray(tr_y[idx]))
        # cosine decay avoids the late-training SI-SNR blow-ups seen at
        # constant lr on this tiny corpus
        cur_lr = lr * 0.5 * (1.0 + np.cos(np.pi * step / max(steps, 1)))
        params, opt = adam_update(params, grads, opt, lr=cur_lr)
        if loss_first is None:
            loss_first = float(loss)
        loss_last = float(loss)
        if log_every and (step % log_every == 0 or step == steps - 1):
            progress(f"    step {step:4d}  loss {float(loss):+.3f} dB")

    # evaluation
    fwd = jax.jit(jax.vmap(lambda x: offline_forward(cfg, params, x)))
    est = np.asarray(fwd(jnp.asarray(ev_x)))
    snr_in = [data.si_snr(ev_x[i], ev_y[i]) for i in range(n_eval)]
    snr_out = [data.si_snr(est[i], ev_y[i]) for i in range(n_eval)]
    si_snri = float(np.mean([o - i for o, i in zip(snr_out, snr_in)]))
    metrics = {
        "si_snri": si_snri,
        "si_snr_noisy": float(np.mean(snr_in)),
        "si_snr_est": float(np.mean(snr_out)),
        "loss_first": loss_first,
        "loss_last": loss_last,
        "steps": steps,
    }
    return params, metrics


# ---------------------------------------------------------------------------
# ASC trainer (GhostNet-style classifier) — used by asc_model.py variants
# ---------------------------------------------------------------------------


def train_classifier(
    forward: Callable,  # forward(params, x (B,feat,T)) -> logits (B, n_classes)
    params: Params,
    feat: int,
    steps: int = 300,
    batch: int = 16,
    t_frames: int = 128,
    n_train: int = 96,
    n_eval: int = 48,
    lr: float = 2e-3,
    seed: int = 0,
    progress: Callable[[str], None] = print,
) -> Tuple[Params, Dict[str, float]]:
    rng = np.random.default_rng(seed + 100)
    tr_x, tr_y = data.scene_batch(rng, n_train, t_frames, feat)
    ev_x, ev_y = data.scene_batch(np.random.default_rng(seed + 101), n_eval, t_frames, feat)

    def loss_fn(p, x, y):
        logits = forward(p, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    opt = adam_init(params)
    rng2 = np.random.default_rng(seed + 9)
    for step in range(steps):
        idx = rng2.integers(0, n_train, size=batch)
        loss, grads = grad_fn(params, jnp.asarray(tr_x[idx]), jnp.asarray(tr_y[idx]))
        params, opt = adam_update(params, grads, opt, lr=lr)
        if step % 100 == 0 or step == steps - 1:
            progress(f"    step {step:4d}  ce {float(loss):.3f}")

    logits = jax.jit(forward)(params, jnp.asarray(ev_x))
    acc = float(np.mean(np.argmax(np.asarray(logits), axis=1) == ev_y))
    return params, {"top1": acc, "steps": steps}
