"""Versioned ``soi.artifact.v1`` weight-artifact exporter (DESIGN.md §13).

Stdlib-only and runnable standalone (no jax import, no package install),
so CI can export an artifact without the training stack:

    python python/compile/artifact.py --synth --name scc2 --scc 2 \
        --out /tmp/soi-art/gen-000001
    python python/compile/artifact.py --from-variant artifacts/scc2 \
        --generation 3 --out artifacts-gen/gen-000003
    python python/compile/artifact.py --verify /tmp/soi-art/gen-000001

Each export emits ``<out>/``:

    artifact.json — schema soi.artifact.v1: name, generation, model
                    config, dtype (+ baked quant scales), train metrics,
                    and a per-tensor table {name, dtype, shape,
                    byte_len, sha256}
    weights.bin   — the tensors concatenated raw little-endian f32 in
                    table order

``--from-variant`` re-packages a trained ``compile.aot`` bundle
(manifest.json + weights.bin) as one integrity-checked generation;
``--synth`` derives the canonical parameter inventory for an explicit
config (mirroring the rust engine's ``synth::param_specs``) and fills it
with deterministic pseudo-random weights — enough to exercise format,
digests, and hot reload without any training stack.

The rust loader (``rust/src/runtime/artifact.rs``) verifies the schema
tag, the full parameter inventory for the declared config, the blob
length, and every sha-256 digest before constructing anything.  CI
cross-checks this writer against that reader: export here, ``soi
inspect-artifact`` must pass; flip one blob byte, it must fail with the
typed digest error.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import shutil
import struct
import sys

SCHEMA = "soi.artifact.v1"
MANIFEST_FILE = "artifact.json"
WEIGHTS_FILE = "weights.bin"

# compile.aot's default model scale (kept in sync by the aot round-trip
# in python/tests)
FEAT = 16
CHANNELS = (12, 16, 20, 24, 28, 32, 40)


# ---------------------------------------------------------------------------
# Config helpers — the same channel arithmetic as model.UNetConfig and
# the rust engine's ModelConfig, rewritten over a plain dict so this
# module stays import-free.
# ---------------------------------------------------------------------------


def depth(cfg: dict) -> int:
    return len(cfg["channels"])


def enc_in_ch(cfg: dict, l: int) -> int:
    return cfg["feat"] if l == 1 else cfg["channels"][l - 2]


def enc_out_ch(cfg: dict, l: int) -> int:
    return cfg["channels"][l - 1]


def dec_out_ch(cfg: dict, l: int) -> int:
    return cfg["channels"][max(l - 2, 0)]


def dec_in_ch(cfg: dict, l: int) -> int:
    d = depth(cfg)
    if l == d:
        return cfg["channels"][d - 1]
    return dec_out_ch(cfg, l + 1) + cfg["channels"][l - 1]


def extrap_of(cfg: dict, p: int) -> str:
    for pos, kind in zip(cfg["scc"], cfg["extrap"]):
        if pos == p:
            return kind
    return "duplicate"


def param_specs(cfg: dict) -> list:
    """Canonical (name, shape) inventory — mirrors rust
    ``synth::param_specs`` (the loader rejects any deviation)."""
    k = cfg["kernel"]
    specs = []

    def conv(name, c_out, c_in, kk):
        specs.append((f"{name}.w", (c_out, c_in, kk)))
        specs.append((f"{name}.b", (c_out,)))

    for l in range(1, depth(cfg) + 1):
        conv(f"enc{l}", enc_out_ch(cfg, l), enc_in_ch(cfg, l), k)
    for l in range(depth(cfg), 0, -1):
        conv(f"dec{l}", dec_out_ch(cfg, l), dec_in_ch(cfg, l), k)
    for p in cfg["scc"]:
        if extrap_of(cfg, p) == "tconv":
            conv(f"up{p}", dec_out_ch(cfg, p), dec_out_ch(cfg, p), 2)
    conv("head", cfg["feat"], dec_out_ch(cfg, 1), 1)
    return specs


# ---------------------------------------------------------------------------
# Deterministic synthetic weights (no numpy): an LCG over u64, mapped to
# small floats.  Values only need to be deterministic and finite — the
# round-trip/integrity machinery is what's under test, not quality.
# ---------------------------------------------------------------------------


def _lcg_floats(n: int, seed: int):
    state = (seed ^ 0x9E3779B97F4A7C15) & (2**64 - 1)
    out = []
    for _ in range(n):
        state = (state * 6364136223846793005 + 1442695040888963407) % 2**64
        # top 24 bits -> [-0.1, 0.1)
        out.append(((state >> 40) / float(1 << 24) - 0.5) * 0.2)
    return out


def synth_blob(shape, seed: int) -> bytes:
    n = 1
    for d in shape:
        n *= d
    return struct.pack(f"<{n}f", *_lcg_floats(n, seed))


# ---------------------------------------------------------------------------
# Writer
# ---------------------------------------------------------------------------


def write_artifact(out_dir, name, generation, config, tensors,
                   dtype="f32", quant=None, train_metrics=None):
    """Write one generation directory atomically (stage + rename), the
    same protocol as the rust saver: a watcher polling the parent never
    sees a half-written generation.

    ``tensors`` is [(name, shape, little-endian f32 bytes)] in canonical
    parameter order.
    """
    table = []
    for tname, shape, blob in tensors:
        n = 1
        for d in shape:
            n *= d
        if len(blob) != 4 * n:
            raise ValueError(f"tensor {tname}: {len(blob)} bytes for shape {shape}")
        table.append({
            "name": tname,
            "dtype": "f32",
            "shape": list(shape),
            "byte_len": len(blob),
            "sha256": hashlib.sha256(blob).hexdigest(),
        })
    manifest = {
        "schema": SCHEMA,
        "name": name,
        "generation": int(generation),
        "config": {
            "feat": config["feat"],
            "channels": list(config["channels"]),
            "kernel": config["kernel"],
            "scc": list(config["scc"]),
            "shift_pos": config.get("shift_pos"),
            "shift": config.get("shift", 1),
            "extrap": list(config.get("extrap", ["duplicate"] * len(config["scc"]))),
            "interp": config.get("interp"),
        },
        "dtype": dtype,
        "quant": quant,
        "train_metrics": train_metrics or {},
        "tensors": table,
    }
    out_dir = os.path.normpath(out_dir)
    parent = os.path.dirname(out_dir) or "."
    os.makedirs(parent, exist_ok=True)
    tmp = f"{out_dir}.tmp-{os.getpid()}"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    with open(os.path.join(tmp, WEIGHTS_FILE), "wb") as f:
        for _, _, blob in tensors:
            f.write(blob)
    with open(os.path.join(tmp, MANIFEST_FILE), "w") as f:
        json.dump(manifest, f, indent=2)
        f.write("\n")
    if os.path.exists(out_dir):
        shutil.rmtree(out_dir)
    os.rename(tmp, out_dir)
    return manifest


def export_synth(cfg: dict, name: str, generation: int, seed: int, out_dir):
    specs = param_specs(cfg)
    tensors = []
    for i, (tname, shape) in enumerate(specs):
        tensors.append((tname, shape, synth_blob(shape, seed + 1000003 * i)))
    return write_artifact(out_dir, name, generation, cfg, tensors)


def export_from_variant(variant_dir, generation: int, out_dir):
    """Re-package a trained ``compile.aot`` bundle as one generation."""
    with open(os.path.join(variant_dir, "manifest.json")) as f:
        man = json.load(f)
    with open(os.path.join(variant_dir, WEIGHTS_FILE), "rb") as f:
        blob = f.read()
    tensors, off = [], 0
    for p in man["params"]:
        n = 1
        for d in p["shape"]:
            n *= d
        tensors.append((p["name"], tuple(p["shape"]), blob[off:off + 4 * n]))
        off += 4 * n
    if off != len(blob):
        raise ValueError(
            f"{variant_dir}: weights.bin holds {len(blob)} bytes, "
            f"params declare {off}"
        )
    return write_artifact(
        out_dir,
        man["name"],
        generation,
        man["config"],
        tensors,
        dtype=man.get("dtype", "f32"),
        quant=man.get("quant"),
        train_metrics=man.get("train_metrics", {}),
    )


# ---------------------------------------------------------------------------
# Verifier — the same checks the rust loader runs, for python-side CI
# smoke and self-tests (the rust reader remains the serving trust
# boundary).
# ---------------------------------------------------------------------------


def verify(dir_path) -> dict:
    """Raise ValueError on the first defect; return the manifest."""
    with open(os.path.join(dir_path, MANIFEST_FILE)) as f:
        man = json.load(f)
    if man.get("schema") != SCHEMA:
        raise ValueError(f"version skew: {man.get('schema')!r} != {SCHEMA!r}")
    cfg = man["config"]
    want = {name: tuple(shape) for name, shape in param_specs(cfg)}
    table = man["tensors"]
    seen = set()
    declared = 0
    for e in table:
        tname = e["name"]
        if tname in seen:
            raise ValueError(f"tensor {tname} listed twice")
        seen.add(tname)
        if tname not in want:
            raise ValueError(f"unexpected tensor {tname}")
        if tuple(e["shape"]) != want[tname]:
            raise ValueError(
                f"tensor {tname}: shape {e['shape']} != {list(want[tname])}"
            )
        n = 1
        for d in e["shape"]:
            n *= d
        if e["byte_len"] != 4 * n:
            raise ValueError(f"tensor {tname}: byte_len {e['byte_len']} != {4 * n}")
        declared += e["byte_len"]
    missing = set(want) - seen
    if missing:
        raise ValueError(f"missing tensors {sorted(missing)}")
    with open(os.path.join(dir_path, WEIGHTS_FILE), "rb") as f:
        blob = f.read()
    if len(blob) != declared:
        raise ValueError(f"truncated: table declares {declared} bytes, blob holds {len(blob)}")
    off = 0
    for e in table:
        piece = blob[off:off + e["byte_len"]]
        off += e["byte_len"]
        got = hashlib.sha256(piece).hexdigest()
        if got != e["sha256"].lower():
            raise ValueError(
                f"tensor {e['name']}: digest mismatch (recorded {e['sha256']}, computed {got})"
            )
    return man


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def _csv_ints(s: str):
    return [int(x) for x in s.split(",") if x.strip()]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    mode = ap.add_mutually_exclusive_group(required=True)
    mode.add_argument("--synth", action="store_true",
                      help="export deterministic synthetic weights for an explicit config")
    mode.add_argument("--from-variant", metavar="DIR",
                      help="re-package a trained compile.aot bundle (manifest.json + weights.bin)")
    mode.add_argument("--verify", metavar="DIR",
                      help="verify an existing artifact (digests, inventory, lengths)")
    ap.add_argument("--out", help="generation directory to write (e.g. root/gen-000001)")
    ap.add_argument("--generation", type=int, default=1)
    ap.add_argument("--name", default=None, help="variant name (--synth; default from --scc)")
    ap.add_argument("--feat", type=int, default=FEAT)
    ap.add_argument("--channels", default=",".join(str(c) for c in CHANNELS))
    ap.add_argument("--kernel", type=int, default=3)
    ap.add_argument("--scc", default="", help="comma-separated S-CC positions")
    ap.add_argument("--shift-pos", type=int, default=None)
    ap.add_argument("--shift", type=int, default=1)
    ap.add_argument("--extrap", default=None,
                    help="comma-separated duplicate|tconv, one per scc position")
    ap.add_argument("--seed", type=int, default=0xC0DE)
    args = ap.parse_args(argv)

    if args.verify:
        try:
            man = verify(args.verify)
        except (OSError, KeyError, ValueError) as e:
            print(f"[artifact] INVALID {args.verify}: {e}", file=sys.stderr)
            return 1
        print(f"[artifact] ok: '{man['name']}' generation {man['generation']}, "
              f"{len(man['tensors'])} tensors, every digest verified")
        return 0

    if not args.out:
        ap.error("--out DIR is required when exporting")
    if args.from_variant:
        man = export_from_variant(args.from_variant, args.generation, args.out)
    else:
        scc = _csv_ints(args.scc)
        cfg = {
            "feat": args.feat,
            "channels": _csv_ints(args.channels),
            "kernel": args.kernel,
            "scc": scc,
            "shift_pos": args.shift_pos,
            "shift": args.shift,
            "extrap": (args.extrap.split(",") if args.extrap
                       else ["duplicate"] * len(scc)),
            "interp": None,
        }
        name = args.name or ("scc" + "_".join(str(p) for p in scc) if scc else "stmc")
        man = export_synth(cfg, name, args.generation, args.seed, args.out)
    total = sum(e["byte_len"] for e in man["tensors"])
    print(f"[artifact] exported '{man['name']}' generation {man['generation']} "
          f"-> {args.out} ({len(man['tensors'])} tensors, {total} weight bytes)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
