"""AOT pipeline: train each SOI variant and lower it to HLO-text artifacts.

Usage (from python/):

    python -m compile.aot --out-dir ../artifacts --variants core
    python -m compile.aot --out-dir ../artifacts --variants all
    python -m compile.aot --out-dir ../artifacts --variants stmc,scc5,sscc5

For every variant this emits ``artifacts/<name>/``:

    manifest.json     — config, state/param specs, phase → executable map,
                        training metrics, per-layer MAC counts
    weights.bin       — trained parameters, concatenated little-endian f32
                        in manifest param order
    step_p<k>.hlo.txt — the streaming step for schedule phase k
                        (deduped: phases with identical graphs share a file)
    pre_p<k>.hlo.txt / rest_p<k>.hlo.txt — the FP precompute split
    offline.hlo.txt   — full-sequence network (T=OFFLINE_T) for batch eval

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids that the image's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Artifacts are cached: a variant is skipped when its manifest exists and
``--force`` is not given.  Training effort is tunable via SOI_TRAIN_STEPS
(default 400) so CI can run with SOI_TRAIN_STEPS=30.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import train as T

OFFLINE_T = 256  # frames per offline-artifact invocation

# Bumped whenever the lowering pipeline changes; cached variants whose
# manifest carries an older stamp are re-lowered (weights are reused).
LOWERING_VERSION = 4

# Default model scale for all speech-separation variants (tiny channels:
# the paper's 14-hour P40 runs are substituted by minutes of CPU Adam —
# DESIGN.md §5).
FEAT = 16
CHANNELS = (12, 16, 20, 24, 28, 32, 40)


def _cfg(**kw) -> M.UNetConfig:
    return M.UNetConfig(feat=FEAT, channels=CHANNELS, **kw)


def variant_registry() -> Dict[str, M.UNetConfig]:
    """Every named variant used by the experiment harness (paper rows)."""
    v: Dict[str, M.UNetConfig] = {}
    v["stmc"] = _cfg()
    # Predictive N baselines (Tables 1/2/5, App. B)
    for n in (1, 2, 3, 4):
        v[f"pred{n}"] = _cfg(shift_pos=1, shift=n)
    # Strided-predictive (App. B): S-CC 4 + whole-input shift N
    for n in (1, 2, 3, 4):
        v[f"spred{n}"] = _cfg(scc=(4,), shift_pos=1, shift=n)
    # PP, single S-CC (Table 1 / 6 / Fig 4)
    for p in range(1, 8):
        v[f"scc{p}"] = _cfg(scc=(p,))
    # PP, two S-CC pairs (Table 1 / Fig 4)
    for pq in [(1, 3), (1, 6), (2, 5), (3, 6), (4, 6), (5, 7), (6, 7)]:
        v[f"scc{pq[0]}_{pq[1]}"] = _cfg(scc=pq)
    # FP: SS-CC (Table 2 / Fig 5)
    for p in (2, 5, 7):
        v[f"sscc{p}"] = _cfg(scc=(p,), shift_pos=p)
    # FP hybrids "S-CC p s" (Table 2)
    for ps in [(1, 3), (1, 6), (2, 5), (3, 6), (4, 6), (5, 6), (6, 7)]:
        v[f"fp{ps[0]}_{ps[1]}"] = _cfg(scc=(ps[0],), shift_pos=ps[1])
    # Interpolation variants (Table 7 / Fig 9) — offline-only evaluation
    for p in (2, 5):
        for kind in ("nearest", "linear", "cubic"):
            v[f"scc{p}_i{kind}"] = _cfg(scc=(p,), interp=kind)
    # Transposed-conv extrapolation (Tables 8/9, App. E)
    for p in (2, 5):
        v[f"scc{p}_tconv"] = _cfg(scc=(p,), extrap="tconv")
    v["scc2_5_tconv"] = _cfg(scc=(2, 5), extrap=("duplicate", "tconv"))
    for p in (2, 5):
        v[f"sscc{p}_tconv"] = _cfg(scc=(p,), shift_pos=p, extrap="tconv")
    return v


CORE_VARIANTS = [
    "stmc", "pred1", "pred2",
    "scc1", "scc2", "scc5", "scc7",
    "scc2_5", "scc1_6",
    "sscc2", "sscc5", "sscc7",
    "fp1_3", "fp2_5",
]


# ---------------------------------------------------------------------------
# HLO lowering
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the rust-loadable format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _specs(shapes: List[Tuple[int, ...]]):
    return [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]


def state_total(cfg: M.UNetConfig) -> int:
    return sum(int(np.prod(s.shape)) for s in M.state_specs(cfg))


def lower_step(cfg: M.UNetConfig, phase: int, part: str) -> str:
    """Lower one streaming step executable to HLO text.

    All partial states travel as ONE flat f32 vector (packed in manifest
    state-spec order): the rust hot path then uploads a single state
    buffer per inference instead of ~20, which removes the dominant
    per-call PJRT overhead (EXPERIMENTS.md §Perf, iteration 1).

    Signatures (all f32, S = packed state length):
      part="all"/"rest": (frame (feat,1), states (S,), *params) -> (out, states')
      part="pre":        (states (S,), *params)                 -> (states',)
    """
    sspecs = M.state_specs(cfg)
    pnames = M.param_names(cfg)
    pshapes = [tuple(v.shape) for v in M.init_params(cfg).values()]
    total = state_total(cfg)

    def unpack(vec):
        states, off = {}, 0
        for s in sspecs:
            n = int(np.prod(s.shape))
            states[s.name] = vec[off : off + n].reshape(s.shape)
            off += n
        return states

    def pack(states):
        return jnp.concatenate([states[s.name].reshape(-1) for s in sspecs])

    def fn(*args):
        i = 0
        if part != "pre":
            frame = args[0]
            i = 1
        else:
            frame = None
        states = unpack(args[i])
        params = {n: args[i + 1 + j] for j, n in enumerate(pnames)}
        out, new_states = M.streaming_step(
            cfg, params, phase, frame, states, use_pallas=True, part=part
        )
        if part == "pre":
            return (pack(new_states),)
        return (out, pack(new_states))

    arg_specs = []
    if part != "pre":
        arg_specs.append(jax.ShapeDtypeStruct((cfg.feat, 1), jnp.float32))
    arg_specs.append(jax.ShapeDtypeStruct((total,), jnp.float32))
    arg_specs += _specs(pshapes)
    lowered = jax.jit(fn, keep_unused=True).lower(*arg_specs)
    return to_hlo_text(lowered)


def lower_offline(cfg: M.UNetConfig, t: int = OFFLINE_T) -> str:
    """Lower the full-sequence network: (x (feat,T), *params) -> (out,)."""
    pnames = M.param_names(cfg)
    pshapes = [tuple(v.shape) for v in M.init_params(cfg).values()]

    def fn(x, *pvals):
        params = dict(zip(pnames, pvals))
        return (M.offline_forward(cfg, params, x, use_pallas=False),)

    arg_specs = [jax.ShapeDtypeStruct((cfg.feat, t), jnp.float32)] + _specs(pshapes)
    return to_hlo_text(jax.jit(fn, keep_unused=True).lower(*arg_specs))


# ---------------------------------------------------------------------------
# MAC accounting (cross-checked against rust/src/complexity in cargo tests)
# ---------------------------------------------------------------------------


def layer_macs(cfg: M.UNetConfig) -> List[dict]:
    """Per-layer MACs per *output frame* in that layer's own rate domain,
    plus the layer's rate divisor — enough for the rust engine cross-check."""
    out = []
    for l in range(1, cfg.depth + 1):
        out.append(
            {
                "name": f"enc{l}",
                "macs": cfg.enc_in_ch(l) * cfg.enc_out_ch(l) * cfg.kernel,
                "rate_div": cfg.r_out(l),
            }
        )
    for l in range(cfg.depth, 0, -1):
        out.append(
            {
                "name": f"dec{l}",
                "macs": cfg.dec_in_ch(l) * cfg.dec_out_ch(l) * cfg.kernel,
                "rate_div": cfg.r_out(l),
            }
        )
    for p in cfg.scc:
        if cfg.extrap_of(p) == "tconv":
            out.append(
                {
                    "name": f"up{p}",
                    "macs": cfg.dec_out_ch(p) * cfg.dec_out_ch(p) * 2,
                    "rate_div": cfg.r_out(p),
                }
            )
    out.append({"name": "head", "macs": cfg.dec_out_ch(1) * cfg.feat, "rate_div": 1})
    return out


def macs_per_frame(cfg: M.UNetConfig) -> float:
    """Average MACs per input frame under the SOI schedule."""
    return sum(e["macs"] / e["rate_div"] for e in layer_macs(cfg))


def precomputed_fraction(cfg: M.UNetConfig) -> float:
    """The paper's "Precomputed %" (as a fraction): the share of the
    *full-rate* network cost that depends only on past data — Table 2's
    published rows equal h(shift_pos) under exactly this definition."""
    if cfg.shift_pos is None:
        return 0.0
    d_enc, d_dec = cfg.delayed_layers()
    total = pre = 0.0
    for e in layer_macs(cfg):
        cost = e["macs"]
        total += cost
        name = e["name"]
        delayed = False
        if name.startswith("enc"):
            delayed = int(name[3:]) in d_enc
        elif name.startswith("dec"):
            delayed = int(name[3:]) in d_dec
        elif name.startswith("up"):
            delayed = int(name[2:]) in d_dec
        elif name == "head":
            delayed = cfg.shift_pos == 1
        pre += cost if delayed else 0.0
    return pre / total


# ---------------------------------------------------------------------------
# Artifact bundle
# ---------------------------------------------------------------------------


def build_variant(
    name: str,
    cfg: M.UNetConfig,
    out_dir: str,
    steps: int,
    force: bool = False,
    progress=print,
) -> dict:
    vdir = os.path.join(out_dir, name)
    man_path = os.path.join(vdir, "manifest.json")
    wpath = os.path.join(vdir, "weights.bin")
    old_manifest = None
    if os.path.exists(man_path):
        with open(man_path) as f:
            old_manifest = json.load(f)
        if old_manifest.get("lowering_version") == LOWERING_VERSION and not force:
            progress(f"[aot] {name}: cached, skipping")
            return old_manifest
    os.makedirs(vdir, exist_ok=True)

    t0 = time.time()
    pnames = M.param_names(cfg)
    reuse = old_manifest is not None and os.path.exists(wpath) and not force
    if reuse:
        # weights already trained under an older lowering — reuse them
        progress(f"[aot] {name}: reusing trained weights, re-lowering")
        raw = np.fromfile(wpath, dtype="<f4")
        params, off = {}, 0
        for n, ref_v in M.init_params(cfg).items():
            k = int(np.prod(ref_v.shape))
            params[n] = jnp.asarray(raw[off : off + k].reshape(ref_v.shape))
            off += k
        assert off == raw.size, f"{name}: weights.bin size mismatch"
        metrics = old_manifest.get("train_metrics", {})
    else:
        progress(f"[aot] {name}: training ({steps} steps) ...")
        params, metrics = T.train_variant(cfg, steps=steps, progress=progress)
        with open(wpath, "wb") as f:
            for n in pnames:
                f.write(np.asarray(params[n], np.float32).tobytes())

    # executables
    executables = {}
    streamable = cfg.interp is None
    if streamable:
        seen: Dict[tuple, str] = {}
        for phase in range(cfg.period):
            parts = ["all"] if cfg.shift_pos is None else ["all", "pre", "rest"]
            for part in parts:
                sig = M.phase_signature(cfg, phase, part)
                key = {"all": "step", "pre": "pre", "rest": "rest"}[part]
                if sig in seen:
                    executables[f"{key}_p{phase}"] = seen[sig]
                    continue
                fname = f"{key}_p{phase}.hlo.txt"
                progress(f"[aot] {name}: lowering {fname}")
                hlo = lower_step(cfg, phase, part)
                with open(os.path.join(vdir, fname), "w") as f:
                    f.write(hlo)
                seen[sig] = fname
                executables[f"{key}_p{phase}"] = fname
    progress(f"[aot] {name}: lowering offline.hlo.txt")
    with open(os.path.join(vdir, "offline.hlo.txt"), "w") as f:
        f.write(lower_offline(cfg))
    executables["offline"] = "offline.hlo.txt"

    manifest = {
        "name": name,
        "lowering_version": LOWERING_VERSION,
        "config": {
            "feat": cfg.feat,
            "channels": list(cfg.channels),
            "kernel": cfg.kernel,
            "scc": list(cfg.scc),
            "shift_pos": cfg.shift_pos,
            "shift": cfg.shift,
            "extrap": list(cfg.extrap),
            "interp": cfg.interp,
        },
        "period": cfg.period,
        "streamable": streamable,
        "offline_t": OFFLINE_T,
        "packed_states": state_total(cfg),
        "states": [{"name": s.name, "shape": list(s.shape)} for s in M.state_specs(cfg)],
        "params": [
            {"name": n, "shape": list(np.asarray(params[n]).shape)} for n in pnames
        ],
        "executables": executables,
        "layer_macs": layer_macs(cfg),
        "macs_per_frame": macs_per_frame(cfg),
        "precomputed_fraction": precomputed_fraction(cfg),
        "param_count": int(M.param_count(cfg)),
        "state_bytes": int(M.state_bytes(cfg)),
        "train_metrics": metrics,
        "build_seconds": round(time.time() - t0, 1),
    }
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=1)
    progress(f"[aot] {name}: done in {manifest['build_seconds']}s "
             f"(SI-SNRi {metrics['si_snri']:+.2f} dB)")
    return manifest


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--variants",
        default="core",
        help="'core', 'all', or comma-separated variant names",
    )
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--steps", type=int,
                    default=int(os.environ.get("SOI_TRAIN_STEPS", "500")))
    args = ap.parse_args(argv)

    reg = variant_registry()
    if args.variants == "all":
        names = list(reg)
    elif args.variants == "core":
        names = CORE_VARIANTS
    else:
        names = [n.strip() for n in args.variants.split(",") if n.strip()]
    unknown = [n for n in names if n not in reg]
    if unknown:
        sys.exit(f"unknown variants: {unknown}; known: {sorted(reg)}")

    os.makedirs(args.out_dir, exist_ok=True)
    t0 = time.time()
    for i, n in enumerate(names):
        print(f"[aot] ===== {n} ({i + 1}/{len(names)}) =====", flush=True)
        build_variant(n, reg[n], args.out_dir, steps=args.steps, force=args.force)
    # top-level index
    index = {"variants": names, "registry": sorted(reg)}
    with open(os.path.join(args.out_dir, "index.json"), "w") as f:
        json.dump(index, f, indent=1)
    print(f"[aot] all done in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
