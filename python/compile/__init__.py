"""Build-time compile package: L1 kernels, L2 models, trainer, AOT pipeline.

Nothing in here runs on the request path — `make artifacts` invokes it once
and the rust coordinator consumes the emitted HLO text + manifests.
"""
