"""Pure-jnp reference oracles for the L1 Pallas kernels.

Everything in this module is the *ground truth* the Pallas kernels and the
streaming (STMC/SOI) inference patterns are tested against.  The layout
convention throughout the compile package is channels-first time series:

    x : (C_in, T)      -- feature sequence, time is the last axis
    w : (C_out, C_in, K) -- 1-D convolution kernel over the time axis
    b : (C_out,)

All convolutions are *causal*: the output at time ``t`` depends only on
inputs at times ``<= t`` (left zero-padding of ``K - 1``).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def causal_pad(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Left-pad the time axis with ``k - 1`` zeros (causal conv padding)."""
    if k <= 1:
        return x
    return jnp.pad(x, ((0, 0), (k - 1, 0)))


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Causal 1-D convolution, stride 1.

    Args:
      x: (C_in, T) input sequence.
      w: (C_out, C_in, K) kernel.
      b: (C_out,) bias.

    Returns:
      (C_out, T) output; ``out[:, t]`` depends on ``x[:, t-K+1 : t+1]``.
    """
    c_out, c_in, k = w.shape
    xp = causal_pad(x, k)  # (C_in, T + K - 1)
    t = x.shape[1]
    # im2col: cols[ci, j, t] = xp[ci, t + j]
    cols = jnp.stack([xp[:, j : j + t] for j in range(k)], axis=1)  # (C_in, K, T)
    w_flat = w.reshape(c_out, c_in * k)
    col_flat = cols.reshape(c_in * k, t)
    return w_flat @ col_flat + b[:, None]


def strided_causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Causal conv with stride 2 over time.

    Keeps the *even*-time outputs of the stride-1 causal conv:
    ``out[:, s] = conv(x)[:, 2 s]`` — the window ends at input time ``2 s``,
    matching the SOI streaming schedule where the compression layer fires
    on even inferences.
    """
    return causal_conv1d(x, w, b)[:, ::2]


def duplicate_upsample(y: jnp.ndarray, t_out: int, shift: int = 0) -> jnp.ndarray:
    """Duplication extrapolation (the paper's S-CC second stage).

    ``up[:, t] = y[:, (t - shift) // 2]`` with zeros for negative indices.

    * ``shift=0`` — partially-predictive (PP) alignment: the value computed
      at even time ``2 s`` is used at times ``2 s`` and ``2 s + 1``
      (eq. 5 of the paper; note X'_{2s} == X'_{2s+1}).
    * ``shift=1`` — fully-predictive (FP) alignment: the value computed at
      ``2 s`` is used at times ``2 s + 1`` and ``2 s + 2`` (eq. 7); every
      use is a *pure prediction* from past data.
    """
    t_idx = jnp.arange(t_out)
    src = (t_idx - shift) // 2
    valid = src >= 0
    src_c = jnp.clip(src, 0, y.shape[1] - 1)
    up = y[:, src_c]
    return jnp.where(valid[None, :], up, 0.0)


def transposed_conv_upsample(
    y: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, t_out: int, shift: int = 0
) -> jnp.ndarray:
    """Learned extrapolation: stride-2 transposed conv over time (App. E).

    ``w`` has shape (C_out, C_in, 2): two output phases per input frame.
    Phase 0 lands on even output times, phase 1 on odd ones, then the whole
    signal is shifted right by ``shift`` like :func:`duplicate_upsample`.
    """
    c_out = w.shape[0]
    s = y.shape[1]
    ph0 = w[:, :, 0] @ y + b[:, None]  # (C_out, S) -> even slots
    ph1 = w[:, :, 1] @ y + b[:, None]  # -> odd slots
    up = jnp.zeros((c_out, 2 * s), dtype=y.dtype)
    up = up.at[:, 0::2].set(ph0)
    up = up.at[:, 1::2].set(ph1)
    if shift > 0:
        up = jnp.pad(up, ((0, 0), (shift, 0)))[:, : 2 * s]
    return up[:, :t_out]


def interp_upsample(y: jnp.ndarray, t_out: int, kind: str = "nearest") -> jnp.ndarray:
    """Interpolation variants of the reconstruction stage (Appendix D).

    Unlike extrapolation these *wait* for the next compressed frame, so the
    odd-time output interpolates between ``y[s]`` and ``y[s+1]`` — better
    quality, one extra frame of latency.

    kinds: ``nearest`` (== duplication of the *later* frame at odd times),
    ``linear`` (paper calls the 1-D case "bilinear"), ``cubic``
    (Catmull-Rom, the 1-D analogue of bicubic).
    """
    t_idx = jnp.arange(t_out)
    s0 = t_idx // 2
    frac = (t_idx % 2).astype(y.dtype) * 0.5
    last = y.shape[1] - 1

    def tap(i):
        return y[:, jnp.clip(i, 0, last)]

    if kind == "nearest":
        # Round half up: odd times take the next frame.
        return tap(s0 + (t_idx % 2))
    if kind == "linear":
        return tap(s0) * (1.0 - frac)[None, :] + tap(s0 + 1) * frac[None, :]
    if kind == "cubic":
        # Catmull-Rom with u = frac
        p0, p1, p2, p3 = tap(s0 - 1), tap(s0), tap(s0 + 1), tap(s0 + 2)
        u = frac[None, :]
        return 0.5 * (
            (2.0 * p1)
            + (-p0 + p2) * u
            + (2.0 * p0 - 5.0 * p1 + 4.0 * p2 - p3) * u**2
            + (-p0 + 3.0 * p1 - 3.0 * p2 + p3) * u**3
        )
    raise ValueError(f"unknown interpolation kind: {kind}")


# ----------------------------------------------------------------------------
# Streaming (single-step) references — the STMC state-carry contract.
# ----------------------------------------------------------------------------


def conv_step(x_t: jnp.ndarray, state: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray):
    """One STMC streaming step of :func:`causal_conv1d`.

    Args:
      x_t:   (C_in, 1) the newly arrived frame.
      state: (C_in, K-1) the previous K-1 input frames (zeros initially).
      w, b:  kernel and bias.

    Returns:
      (out, new_state): out (C_out, 1); new_state (C_in, K-1) — the window
      shifted by one.  Feeding a sequence frame-by-frame reproduces
      ``causal_conv1d`` exactly (STMC's core guarantee).
    """
    window = jnp.concatenate([state, x_t], axis=1)  # (C_in, K)
    c_out, c_in, k = w.shape
    out = w.reshape(c_out, c_in * k) @ window.reshape(c_in * k, 1) + b[:, None]
    return out, window[:, 1:]


def dense(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Row-major dense layer: x (N,) @ w (M, N) -> (M,)."""
    return w @ x + b


# ----------------------------------------------------------------------------
# Int8 reference kernels — the python mirror of ``rust/src/quant`` (the
# quantized execution subsystem, DESIGN.md §10).  These are plain numpy
# (integer/LUT semantics, exact f32 accumulation order) so they stay
# bit-comparable to the rust kernels; the golden vectors baked into
# ``rust/tests/cross_check.rs`` are generated from exactly these
# functions, keeping the python mirror the validation path on
# toolchain-less images.
# ----------------------------------------------------------------------------

Q_W = 127       # symmetric int8 weight code range
Q_ACT = 32767   # symmetric s16 activation code range


def _round_half_away(x):
    """Mirror rust's ``f32::round`` (half away from zero); numpy's
    ``round`` rounds half to even and must not be used here."""
    x = np.asarray(x)
    return np.where(x >= 0, np.floor(x + 0.5), np.ceil(x - 0.5))


def int8_quantize_weights(w, group=None):
    """Per-channel, group-refined symmetric int8 weight quantization.

    Mirrors ``quant::qtensor::quantize_weights``: ``w`` is a
    ``(C_out, C_in, K)`` f32 kernel; each run of ``group`` trailing
    elements (default ``K`` — one group per (out, in) pair) shares one
    scale ``max|group| / 127`` (1.0 for an all-zero group) and codes
    ``clamp(round(w / s), -127, 127)``.

    Returns ``(q, scales)``: ``q`` int8 with ``w``'s shape, ``scales``
    f32 of shape ``(w.size // group,)`` in row-major group order.
    """
    w = np.asarray(w, np.float32)
    if group is None:
        group = w.shape[-1]
    flat = w.reshape(-1, group)
    maxabs = np.abs(flat).max(axis=1)
    scales = np.where(maxabs == 0.0, np.float32(1.0), maxabs / np.float32(Q_W)).astype(
        np.float32
    )
    q = np.clip(_round_half_away(flat / scales[:, None]), -Q_W, Q_W).astype(np.int8)
    return q.reshape(w.shape), scales


def s16_quantize(v, scale):
    """s16 activation quantization: ``clamp(round(v / s), ±32767)``
    (mirrors ``quant::kernels::quantize_act`` / ``requant``)."""
    v = np.asarray(v, np.float32)
    q = _round_half_away(v / np.float32(scale))
    return np.clip(q, -Q_ACT, Q_ACT).astype(np.int64)


def int8_conv_win(q, scales, s_x, b, win_q):
    """The quantized step conv: i32 group dots + f32 scale folds + bias.

    Mirrors ``quant::kernels::conv_win_batch_q`` at ``B == 1``: ``q``
    int8 ``(C_out, C_in, K)``, ``scales`` per-(out, in) group scales,
    ``s_x`` the input activation scale (scalar or per-input-channel
    vector), ``b`` f32 bias, ``win_q`` the flattened ``(C_in · K,)``
    window of s16 codes.  Each (out, in) group accumulates an exact
    integer dot, the groups fold in input-channel order as f32 (the
    combine factor is ``s_x(i) · s_w(o, i)``), and the f32 bias is added
    last — the exact accumulation order of the rust kernel, so outputs
    are bit-comparable.
    """
    q = np.asarray(q)
    c_out, c_in, k = q.shape
    scales = np.asarray(scales, np.float32).reshape(c_out, c_in)
    sx = np.broadcast_to(np.asarray(s_x, np.float32), (c_in,))
    win = np.asarray(win_q, np.int64).reshape(c_in, k)
    out = np.zeros(c_out, np.float32)
    for o in range(c_out):
        pre = np.float32(0.0)
        for i in range(c_in):
            acc = int((q[o, i].astype(np.int64) * win[i]).sum())
            g = np.float32(sx[i] * scales[o, i])
            pre = np.float32(pre + np.float32(g * np.float32(acc)))
        out[o] = np.float32(pre + np.float32(b[o]))
    return out


def elu_lut_table(scale):
    """The interpolated ELU LUT knots of ``quant::kernels::EluLut``:
    ``table[j] = round(expm1(-(j · 32) · s) / s)`` for ``j in 0..=1024``
    (f64 math, mirroring the rust construction)."""
    j = np.arange(1025, dtype=np.float64)
    return _round_half_away(np.expm1(-(j * 32.0) * float(scale)) / float(scale)).astype(
        np.int64
    )


def elu_lut_apply(table, q):
    """Integer LUT + interpolation of ``EluLut::apply``: positive codes
    pass through; negative codes interpolate between the two surrounding
    knots with round-to-nearest in pure integer math."""
    q = np.asarray(q, np.int64)
    u = -q
    seg = np.clip(u >> 5, 0, 1023)
    r = u & 31
    lo = table[seg]
    hi = table[seg + 1]
    neg = lo + (((hi - lo) * r + 16) >> 5)
    return np.where(q >= 0, q, neg).astype(np.int64)
