"""L1 Pallas kernels: streaming (STMC) and offline causal 1-D convolution.

Hardware adaptation (DESIGN.md §4): the paper targets MCU/CPU streaming, so
the TPU mapping is about making the conv MXU-shaped rather than porting CUDA
concepts.  Both kernels phrase the convolution as a single matmul

    out = W_flat (C_out × C_in·K)  @  im2col(window) (C_in·K × T_tile)

which is exactly the systolic-array-friendly contraction.  Weights are small
(≤ a few hundred KB for every variant in this repo) and live in VMEM for the
whole kernel; the input window is the streamed HBM→VMEM operand, tiled along
time by ``BlockSpec``-style dynamic slices.

All kernels are built with ``interpret=True``: the CPU PJRT plugin cannot run
Mosaic custom-calls, and interpret mode lowers the kernel body to plain HLO
that the rust runtime executes.  Real-TPU numbers are estimated analytically
(EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Time-tile for the offline kernel.  128 matches the MXU lane width; the
# im2col block for C_in=64, K=3 is 64·3×128 f32 = 96 KB — comfortably VMEM
# resident together with the weight tile.
DEFAULT_TILE_T = 128


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


# ----------------------------------------------------------------------------
# Streaming step kernel (the request-path hot spot)
# ----------------------------------------------------------------------------


def _conv_step_kernel(win_ref, w_ref, b_ref, o_ref):
    """out[b, :] = W_flat @ win[b, :] + b  for every stream in the batch.

    win_ref: (B, C_in·K)  — per-stream conv windows (state ‖ new frame)
    w_ref:   (C_out, C_in·K)
    b_ref:   (C_out,)
    o_ref:   (B, C_out)
    """
    win = win_ref[...]
    w = w_ref[...]
    o_ref[...] = (
        jax.lax.dot_general(
            win,
            w,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        + b_ref[...][None, :]
    )


def conv_step(window: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """One streaming conv step over a batch of prepared windows.

    Args:
      window: (B, C_in, K) — per-stream window: previous ``K-1`` input
        frames (the STMC state) concatenated with the new frame.
      w: (C_out, C_in, K) kernel.
      b: (C_out,) bias.

    Returns:
      (B, C_out) — one output frame per stream.
    """
    bsz, c_in, k = window.shape
    c_out = w.shape[0]
    win_flat = window.reshape(bsz, c_in * k)
    w_flat = w.reshape(c_out, c_in * k)
    return pl.pallas_call(
        _conv_step_kernel,
        out_shape=jax.ShapeDtypeStruct((bsz, c_out), window.dtype),
        interpret=True,
    )(win_flat, w_flat, b)


# ----------------------------------------------------------------------------
# Offline (full-sequence) kernel — used by the `offline` artifacts and as
# the training-time forward pass, so train == serve numerics.
# ----------------------------------------------------------------------------


def _conv_full_kernel(xp_ref, w_ref, b_ref, o_ref, *, k: int, tile_t: int):
    """Grid over time tiles; each program computes a (C_out, tile_t) block.

    xp_ref: (C_in, T_pad + K - 1) causally padded input (full, HBM-resident;
            each program slices its overlapping window — overlap of K-1
            columns is why we index manually instead of a disjoint BlockSpec)
    w_ref:  (C_out, C_in·K) flattened weights (VMEM-resident)
    o_ref:  (C_out, T_pad)
    """
    i = pl.program_id(0)
    xw = xp_ref[:, pl.dslice(i * tile_t, tile_t + k - 1)]  # (C_in, tile_t + K - 1)
    # im2col with the (ci, j) -> ci*K + j ordering that matches w.reshape().
    cols = jnp.stack([xw[:, j : j + tile_t] for j in range(k)], axis=1)
    cols = cols.reshape(xw.shape[0] * k, tile_t)
    out = (
        jax.lax.dot_general(
            w_ref[...],
            cols,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        + b_ref[...][:, None]
    )
    o_ref[:, pl.dslice(i * tile_t, tile_t)] = out


def conv_full(
    x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, tile_t: int = DEFAULT_TILE_T
) -> jnp.ndarray:
    """Causal conv over a full sequence: x (C_in, T) -> (C_out, T)."""
    c_out, c_in, k = w.shape
    t = x.shape[1]
    t_pad = _ceil_to(max(t, 1), tile_t)
    # causal left pad (K-1) + right pad up to the tile multiple
    xp = jnp.pad(x, ((0, 0), (k - 1, t_pad - t)))
    w_flat = w.reshape(c_out, c_in * k)
    kern = functools.partial(_conv_full_kernel, k=k, tile_t=tile_t)
    out = pl.pallas_call(
        kern,
        grid=(t_pad // tile_t,),
        out_shape=jax.ShapeDtypeStruct((c_out, t_pad), x.dtype),
        interpret=True,
    )(xp, w_flat, b)
    return out[:, :t]


# ----------------------------------------------------------------------------
# Dense kernel (classifier heads)
# ----------------------------------------------------------------------------


def _dense_kernel(x_ref, w_ref, b_ref, o_ref):
    o_ref[...] = (
        jax.lax.dot_general(
            x_ref[...],
            w_ref[...],
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        + b_ref[...][None, :]
    )


def dense(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Batched dense layer: x (B, N) @ w (M, N)^T + b -> (B, M)."""
    bsz = x.shape[0]
    m = w.shape[0]
    return pl.pallas_call(
        _dense_kernel,
        out_shape=jax.ShapeDtypeStruct((bsz, m), x.dtype),
        interpret=True,
    )(x, w, b)


def vmem_footprint_bytes(c_in: int, c_out: int, k: int, tile_t: int = DEFAULT_TILE_T) -> dict:
    """Analytic VMEM footprint of one `conv_full` program (f32).

    Used by the §Perf tables: weights + im2col block + output block must fit
    the ~16 MB/core VMEM budget with double-buffering headroom.
    """
    w_bytes = c_out * c_in * k * 4
    col_bytes = c_in * k * tile_t * 4
    in_bytes = c_in * (tile_t + k - 1) * 4
    out_bytes = c_out * tile_t * 4
    return {
        "weights": w_bytes,
        "input_window": in_bytes,
        "im2col": col_bytes,
        "output": out_bytes,
        "total": w_bytes + col_bytes + in_bytes + out_bytes,
    }
