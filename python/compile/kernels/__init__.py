"""L1 kernels: Pallas implementations + pure-jnp reference oracles."""
from . import ref  # noqa: F401
from .stmc_conv import conv_full, conv_step, dense, vmem_footprint_bytes  # noqa: F401
