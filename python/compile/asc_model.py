"""GhostNet-style acoustic-scene classifier (paper §3.2, Table 4) —
build-time evaluation substrate.

Table 4 reports top-1 accuracy + complexity for Baseline/STMC/SOI at seven
sizes.  Accuracy-wise Baseline == STMC by construction (STMC is an exact
inference-pattern transformation), so the quantity of interest is the
STMC → SOI accuracy delta; complexity columns are analytic
(rust `complexity::ghostnet`).

This module trains tiny GhostNet-style classifiers on the synthetic scene
task (DESIGN.md §5) in two variants per size — STMC-equivalent (stride-free
causal convs) and SOI (strided middle blocks + duplication upsample + skip
connection) — and writes `artifacts/asc_results.json` consumed by the rust
`table4` driver.

A ghost module makes half its output with a full conv and half with a cheap
depthwise conv over the primary half (Han et al. 2020).
"""

from __future__ import annotations

import json
import os
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .train import train_classifier

FEAT = 20  # spectral-frame features
WIDTHS = (16, 24, 40, 40, 64, 64, 80, 96)
N_CLASSES = 10

# Width multipliers — mirror rust complexity::ghostnet::SIZES (I..III are
# trained; larger sizes are complexity-only, like the paper's P40 budget
# substitution in DESIGN.md §5).
TRAINED_SIZES = [("I", 0.25), ("II", 0.40), ("III", 0.55)]


def _ch(base: int, mult: float) -> int:
    return max(int(round(base * mult)), 2)


def ghost_params(mult: float, soi: bool, seed: int = 0) -> Dict[str, jnp.ndarray]:
    rng = np.random.default_rng(seed)
    params: Dict[str, jnp.ndarray] = {}

    def conv(name, c_out, c_in, k):
        s = float(np.sqrt(2.0 / (c_in * k)))
        params[f"{name}.w"] = jnp.asarray(
            rng.standard_normal((c_out, c_in, k)) * s, jnp.float32
        )
        params[f"{name}.b"] = jnp.zeros((c_out,), jnp.float32)

    c_in = FEAT
    for i, w in enumerate(WIDTHS):
        c_out = _ch(w, mult)
        half = max(c_out // 2, 1)
        conv(f"g{i}.primary", half, c_in, 3)
        conv(f"g{i}.cheap", half, half, 3)  # depthwise approximated as grouped-1
        c_in = 2 * half
    if soi:
        # merge conv after the upsample: [up(d5) ‖ cast(skip)] -> c5
        c5 = 2 * max(_ch(WIDTHS[5], mult) // 2, 1)
        conv("soi_skip", c5, 2 * c5, 1)
    conv("head", N_CLASSES, c_in, 1)
    return params


def ghost_module(params, name: str, x: jnp.ndarray) -> jnp.ndarray:
    """x (C_in, T) -> (2*half, T): primary conv + cheap conv of the half."""
    p = ref.causal_conv1d(x, params[f"{name}.primary.w"], params[f"{name}.primary.b"])
    c = ref.causal_conv1d(p, params[f"{name}.cheap.w"], params[f"{name}.cheap.b"])
    return jax.nn.elu(jnp.concatenate([p, c], axis=0))


def forward(params, x: jnp.ndarray, mult: float, soi: bool) -> jnp.ndarray:
    """x (FEAT, T) -> logits (N_CLASSES,).

    SOI variant: blocks 2..5 run in a stride-2 compressed domain entered at
    block 2 and left (duplication upsample + skip concat) after block 5 —
    the placement `complexity::ghostnet` costs out (~16% reduction).
    """
    cur = x
    skip = None
    for i in range(len(WIDTHS)):
        if soi and i == 2:
            skip = cur
            cur = cur[:, ::2]  # compression (stride 2 in time)
        cur = ghost_module(params, f"g{i}", cur)
        if soi and i == 5:
            cur = ref.duplicate_upsample(cur, skip.shape[1])
            # skip connection re-injects current-rate data
            merged = jnp.concatenate([cur, ghost_cast(skip, cur.shape[0])], axis=0)
            cur = jax.nn.elu(
                ref.causal_conv1d(merged, params["soi_skip.w"], params["soi_skip.b"])
            )
    pooled = cur.mean(axis=1, keepdims=True)  # global average over time
    logits = ref.causal_conv1d(pooled, params["head.w"], params["head.b"])
    return logits[:, 0]


def ghost_cast(skip: jnp.ndarray, c: int) -> jnp.ndarray:
    """Match the skip tensor's channel count to `c` by tile/truncate (a
    parameter-free projection, keeping the substitution lightweight)."""
    reps = -(-c // skip.shape[0])
    return jnp.tile(skip, (reps, 1))[:c]


def batched_forward(mult: float, soi: bool):
    def fwd(params, xb):
        return jax.vmap(lambda x: forward(params, x, mult, soi))(xb)

    return fwd


def run(out_path: str, steps: int = 250, seeds: int = 2) -> dict:
    """Train STMC + SOI at each size; write asc_results.json."""
    results: List[dict] = []
    for label, mult in TRAINED_SIZES:
        for soi in (False, True):
            accs = []
            for seed in range(seeds):
                params = ghost_params(mult, soi, seed=seed)
                fwd = batched_forward(mult, soi)
                _, m = train_classifier(
                    fwd,
                    params,
                    feat=FEAT,
                    steps=steps,
                    seed=seed,
                    progress=lambda s: None,
                )
                accs.append(m["top1"])
            results.append(
                {
                    "size": label,
                    "mult": mult,
                    "method": "SOI" if soi else "STMC",
                    "top1_mean": float(np.mean(accs)),
                    "top1_std": float(np.std(accs)),
                    "seeds": seeds,
                    "steps": steps,
                }
            )
            print(
                f"[asc] {label} {'SOI ' if soi else 'STMC'} "
                f"top1 {np.mean(accs):.3f} ± {np.std(accs):.3f}",
                flush=True,
            )
    out = {"feat": FEAT, "n_classes": N_CLASSES, "results": results}
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    import sys

    out = sys.argv[1] if len(sys.argv) > 1 else "../artifacts/asc_results.json"
    run(out)
