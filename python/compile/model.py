"""L2: the causal streaming U-Net and its SOI variants.

This module is the paper's §2 in executable form.  One `UNetConfig`
describes a variant (S-CC positions, shift placement for FP, extrapolation
kind); from it we derive

* `offline_forward`   — the full-sequence network (training + the
  equivalence oracle + the `offline` artifact),
* `init_states`       — the STMC partial-state pytree,
* `streaming_step`    — one single-frame inference for a given phase of the
  SOI schedule (the `step_*` artifacts),
* the FP split (``part="pre"`` / ``part="rest"``): the portion of an
  inference that only depends on past data (runnable before the frame
  arrives) and the remainder (DESIGN.md §6).

Layout: frames are channels-first, (C, T) offline and (C, 1) streaming.

Scheduling model (matches the paper's eq. 3–7):

* Encoder layer ``l`` has input-rate divisor ``R_in(l) = 2^|{p ∈ scc : p < l}|``
  and *ticks* (receives a new input frame) when ``t % R_in(l) == 0``.
* A compression layer ``p ∈ scc`` additionally *fires* (computes) only when
  ``t % 2·R_in(p) == 0`` — on other ticks it just pushes the frame into its
  STMC window state (the paper's eq. 4 "odd inference" branch).
* Decoder layer ``l`` lives in the same rate domain as encoder output ``l``
  (``R_out(l)``); for ``l ∈ scc`` its activation is duplicated back to the
  ``R_in(l)`` domain (eq. 5; an FP shift moves this to eq. 7 semantics).
* An FP shift at position ``s`` inserts a `shift`-frame delay line at the
  input of encoder layer ``s``: everything from encoder ``s`` through
  decoder ``s`` then depends only on strictly-past data and is
  *precomputable*; skip connections below ``s`` re-inject current data
  (this is exactly why the paper's "Precomputed %" column equals the cost
  fraction of the region ``s..mirror(s)``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .kernels.stmc_conv import conv_full as pallas_conv_full
from .kernels.stmc_conv import conv_step as pallas_conv_step

Params = Dict[str, jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class UNetConfig:
    """One SOI variant of the speech-separation U-Net.

    Attributes:
      feat: input frame size (raw samples per frame == input channels).
      channels: encoder output channels, one per encoder layer.
      kernel: causal conv kernel size along time.
      scc: sorted encoder positions (1-based) carrying an S-CC pair
        (strided compression + mirrored extrapolation).  Empty = pure STMC.
      shift_pos: FP shift position ``s`` (1-based encoder layer index); the
        delay line sits at that layer's input.  ``None`` = PP / plain STMC.
        ``s == p`` for some ``p ∈ scc`` is the paper's SS-CC; ``s == 1``
        with empty scc is the paper's "Predictive N" baseline.
      shift: delay length in layer-``s``-input-rate frames (paper App. B
        tests 1..4).
      extrap: extrapolation kind per scc position: "duplicate" or "tconv"
        (learned transposed conv, App. E).  A single string applies to all.
      interp: if set, replaces extrapolation by interpolation (App. D,
        offline evaluation only — costs one frame of latency online):
        "nearest" | "linear" | "cubic".
    """

    feat: int = 32
    channels: Tuple[int, ...] = (24, 32, 40, 48, 56, 64, 80)
    kernel: int = 3
    scc: Tuple[int, ...] = ()
    shift_pos: Optional[int] = None
    shift: int = 1
    extrap: Tuple[str, ...] | str = "duplicate"
    interp: Optional[str] = None

    def __post_init__(self):
        assert tuple(sorted(self.scc)) == tuple(self.scc), "scc must be sorted"
        assert all(1 <= p <= self.depth for p in self.scc)
        if self.shift_pos is not None:
            assert 1 <= self.shift_pos <= self.depth
            assert self.shift >= 1
        if isinstance(self.extrap, str):
            object.__setattr__(self, "extrap", (self.extrap,) * len(self.scc))
        assert len(self.extrap) == len(self.scc)

    # ---- topology helpers -------------------------------------------------

    @property
    def depth(self) -> int:
        return len(self.channels)

    @property
    def period(self) -> int:
        """Length of the repeating inference pattern."""
        return 2 ** len(self.scc)

    def r_in(self, l: int) -> int:
        """Rate divisor of encoder layer l's input domain (l is 1-based)."""
        return 2 ** sum(1 for p in self.scc if p < l)

    def r_out(self, l: int) -> int:
        """Rate divisor of encoder layer l's output domain."""
        return 2 ** sum(1 for p in self.scc if p <= l)

    def enc_in_ch(self, l: int) -> int:
        return self.feat if l == 1 else self.channels[l - 2]

    def enc_out_ch(self, l: int) -> int:
        return self.channels[l - 1]

    def dec_out_ch(self, l: int) -> int:
        return self.channels[max(l - 2, 0)]

    def dec_in_ch(self, l: int) -> int:
        d = self.depth
        if l == d:
            return self.channels[d - 1]
        return self.dec_out_ch(l + 1) + self.channels[l - 1]

    def extrap_of(self, p: int) -> str:
        return self.extrap[self.scc.index(p)]

    def delayed_layers(self) -> Tuple[set, set]:
        """(encoder layers, decoder layers) inside the FP-delayed region."""
        if self.shift_pos is None:
            return set(), set()
        s = self.shift_pos
        return set(range(s, self.depth + 1)), set(range(s, self.depth + 1))


# ----------------------------------------------------------------------------
# Parameters
# ----------------------------------------------------------------------------


def init_params(cfg: UNetConfig, seed: int = 0) -> Params:
    """He-initialised parameter dict; key order is the manifest order."""
    rng = np.random.default_rng(seed)
    params: Params = {}

    def mk_conv(name, c_out, c_in, k):
        scale = float(np.sqrt(2.0 / (c_in * k)))
        params[f"{name}.w"] = jnp.asarray(
            rng.standard_normal((c_out, c_in, k)) * scale, jnp.float32
        )
        params[f"{name}.b"] = jnp.zeros((c_out,), jnp.float32)

    for l in range(1, cfg.depth + 1):
        mk_conv(f"enc{l}", cfg.enc_out_ch(l), cfg.enc_in_ch(l), cfg.kernel)
    for l in range(cfg.depth, 0, -1):
        mk_conv(f"dec{l}", cfg.dec_out_ch(l), cfg.dec_in_ch(l), cfg.kernel)
    for p in cfg.scc:
        if cfg.extrap_of(p) == "tconv":
            mk_conv(f"up{p}", cfg.dec_out_ch(p), cfg.dec_out_ch(p), 2)
    mk_conv("head", cfg.feat, cfg.dec_out_ch(1), 1)
    return params


def param_names(cfg: UNetConfig) -> List[str]:
    return list(init_params(cfg).keys())


def param_count(cfg: UNetConfig) -> int:
    return sum(int(np.prod(v.shape)) for v in init_params(cfg).values())


# ----------------------------------------------------------------------------
# Offline forward (training / oracle / `offline` artifact)
# ----------------------------------------------------------------------------


def _delay(x: jnp.ndarray, d: int) -> jnp.ndarray:
    """Right-shift along time by d frames (zeros in front)."""
    return jnp.pad(x, ((0, 0), (d, 0)))[:, : x.shape[1]]


def offline_forward(
    cfg: UNetConfig, params: Params, x: jnp.ndarray, use_pallas: bool = False
) -> jnp.ndarray:
    """Full-sequence forward pass.

    Args:
      cfg: variant config.  ``x.shape[1]`` must be divisible by cfg.period.
      params: parameter dict from :func:`init_params`.
      x: (feat, T) input frames.
      use_pallas: route convs through the L1 Pallas kernel (used when
        lowering the `offline` artifact so the kernel is in the HLO).

    Returns:
      (feat, T) — the denoised frames.
    """
    assert x.shape[1] % cfg.period == 0, "T must be a multiple of cfg.period"
    conv = pallas_conv_full if use_pallas else ref.causal_conv1d

    enc: List[jnp.ndarray] = [x]
    cur = x
    for l in range(1, cfg.depth + 1):
        if cfg.shift_pos == l:
            cur = _delay(cur, cfg.shift)
        w, b = params[f"enc{l}.w"], params[f"enc{l}.b"]
        y = conv(cur, w, b)
        if l in cfg.scc:
            y = y[:, ::2]
        cur = jax.nn.elu(y)
        enc.append(cur)

    d = None
    for l in range(cfg.depth, 0, -1):
        inp = enc[cfg.depth] if l == cfg.depth else jnp.concatenate([d, enc[l]], axis=0)
        w, b = params[f"dec{l}.w"], params[f"dec{l}.b"]
        d = jax.nn.elu(conv(inp, w, b))
        if l in cfg.scc:
            t_out = enc[l - 1].shape[1]
            if cfg.interp is not None:
                d = ref.interp_upsample(d, t_out, cfg.interp)
            elif cfg.extrap_of(l) == "tconv":
                d = ref.transposed_conv_upsample(
                    d, params[f"up{l}.w"], params[f"up{l}.b"], t_out
                )
            else:
                d = ref.duplicate_upsample(d, t_out)
    return conv(d, params["head.w"], params["head.b"])


# ----------------------------------------------------------------------------
# Streaming states
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StateSpec:
    name: str
    shape: Tuple[int, ...]


def state_specs(cfg: UNetConfig) -> List[StateSpec]:
    """Ordered partial-state inventory for one stream (the manifest order).

    * ``enc{l}.win`` / ``dec{l}.win`` — STMC conv windows, (C_in, K-1).
    * ``up{p}.cache`` — last extrapolated decoder-p activation, (C, 1)
      (for "tconv" extrapolation the cache holds both phases, (C, 2)).
    * ``shift.fifo`` — FP delay line at encoder ``shift_pos``, (C, shift).
    * ``fp.handoff`` — FP boundary value from the precompute pass to the
      rest pass (only when ``shift_pos`` is set and not an SS-CC position).
    """
    specs: List[StateSpec] = []
    k = cfg.kernel
    for l in range(1, cfg.depth + 1):
        specs.append(StateSpec(f"enc{l}.win", (cfg.enc_in_ch(l), k - 1)))
    for l in range(cfg.depth, 0, -1):
        specs.append(StateSpec(f"dec{l}.win", (cfg.dec_in_ch(l), k - 1)))
    for p in cfg.scc:
        width = 2 if cfg.extrap_of(p) == "tconv" else 1
        specs.append(StateSpec(f"up{p}.cache", (cfg.dec_out_ch(p), width)))
    if cfg.shift_pos is not None:
        s = cfg.shift_pos
        specs.append(StateSpec("shift.fifo", (cfg.enc_in_ch(s), cfg.shift)))
        if s not in cfg.scc:
            ho = cfg.feat if s == 1 else cfg.dec_out_ch(s)
            specs.append(StateSpec("fp.handoff", (ho, 1)))
    return specs


def init_states(cfg: UNetConfig) -> Dict[str, jnp.ndarray]:
    return {s.name: jnp.zeros(s.shape, jnp.float32) for s in state_specs(cfg)}


def state_bytes(cfg: UNetConfig) -> int:
    """Peak per-stream partial-state memory (f32)."""
    return sum(int(np.prod(s.shape)) * 4 for s in state_specs(cfg))


# ----------------------------------------------------------------------------
# Streaming step
# ----------------------------------------------------------------------------


def _conv_step(window: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, use_pallas: bool):
    if use_pallas:
        return pallas_conv_step(window[None], w, b)[0][:, None]
    c_out, c_in, k = w.shape
    return w.reshape(c_out, c_in * k) @ window.reshape(c_in * k, 1) + b[:, None]


def _layer_tick(
    name: str,
    cur: jnp.ndarray,
    states: Dict[str, jnp.ndarray],
    params: Params,
    compute: bool,
    use_pallas: bool,
):
    """Push `cur` into the layer's STMC window; optionally compute."""
    win = jnp.concatenate([states[f"{name}.win"], cur], axis=1)
    states[f"{name}.win"] = win[:, 1:]
    if not compute:
        return None
    return _conv_step(win, params[f"{name}.w"], params[f"{name}.b"], use_pallas)


def streaming_step(
    cfg: UNetConfig,
    params: Params,
    phase: int,
    frame: Optional[jnp.ndarray],
    states: Dict[str, jnp.ndarray],
    use_pallas: bool = False,
    part: str = "all",
) -> Tuple[Optional[jnp.ndarray], Dict[str, jnp.ndarray]]:
    """One single-frame SOI inference at schedule position ``phase``.

    Args:
      phase: ``t % cfg.period`` — selects which layers tick/fire.
      frame: (feat, 1) the newly arrived frame (None allowed for
        part="pre", which must not touch it).
      states: state dict (not mutated; an updated copy is returned).
      part: "all" = the whole inference; "pre" = only the FP-delayed region
        (depends exclusively on past data; callable before the frame
        arrives); "rest" = the complement, consuming the fresh frame and
        the handoff produced by "pre".  ``pre ∘ rest == all`` exactly.

    Returns:
      (out, new_states): out (feat, 1), or None for part="pre".
    """
    assert part in ("all", "pre", "rest")
    if cfg.interp is not None:
        raise NotImplementedError(
            "interpolation variants are evaluated offline (App. D adds a "
            "frame of latency online); use offline_forward"
        )
    states = dict(states)
    d_enc, d_dec = cfg.delayed_layers()
    if part == "pre":
        assert cfg.shift_pos is not None, "precompute only exists for FP variants"

    def in_part(enc: bool, l: int) -> bool:
        if part == "all":
            return True
        delayed = l in (d_enc if enc else d_dec)
        return delayed if part == "pre" else not delayed

    s = cfg.shift_pos
    depth = cfg.depth

    # ---- encoder ----
    enc_out: Dict[int, Optional[jnp.ndarray]] = {}
    cur: Optional[jnp.ndarray] = frame if part != "pre" else None
    for l in range(1, depth + 1):
        if phase % cfg.r_in(l) != 0:
            cur = None
            enc_out[l] = None
            continue
        # FP delay line at the input of layer s: read the oldest entry
        # *before* pushing (the pre pass reads, the rest pass pushes).
        if s == l:
            delayed_in = states["shift.fifo"][:, :1]
            if part != "pre":
                assert cur is not None
                states["shift.fifo"] = jnp.concatenate(
                    [states["shift.fifo"][:, 1:], cur], axis=1
                )
            cur = delayed_in if in_part(True, l) else None
        if not in_part(True, l):
            cur = None
            enc_out[l] = None
            continue
        assert cur is not None, f"enc{l}: no input frame at phase {phase}"
        fires = (phase % (2 * cfg.r_in(l)) == 0) if l in cfg.scc else True
        out = _layer_tick(f"enc{l}", cur, states, params, fires, use_pallas)
        cur = jax.nn.elu(out) if out is not None else None
        enc_out[l] = cur

    # ---- decoder ----
    d: Optional[jnp.ndarray] = None
    for l in range(depth, 0, -1):
        computed_here = False
        if phase % cfg.r_out(l) == 0:
            if not in_part(False, l):
                d = None
            else:
                if l == depth:
                    inp = enc_out[l]
                else:
                    upper = d
                    if part == "rest" and (l + 1 in d_dec) and (l + 1) not in cfg.scc:
                        # boundary: the delayed d_{l+1} was produced by the
                        # pre pass and parked in the handoff slot.
                        upper = states["fp.handoff"]
                    assert upper is not None, f"dec{l}: missing deep input"
                    assert enc_out[l] is not None, f"dec{l}: missing skip"
                    inp = jnp.concatenate([upper, enc_out[l]], axis=0)
                y = _layer_tick(f"dec{l}", inp, states, params, True, use_pallas)
                d = jax.nn.elu(y)
                computed_here = True
        # extrapolation back to the R_in(l) domain.  The *write* belongs to
        # whichever pass computed the fresh d_l; the *read* belongs to the
        # pass that computes d_{l-1} (or the head, for l == 1).
        if l in cfg.scc and phase % cfg.r_in(l) == 0:
            cache = f"up{l}.cache"
            fresh = phase % cfg.r_out(l) == 0
            if fresh and computed_here:  # write
                assert d is not None
                if cfg.extrap_of(l) == "tconv":
                    w, b = params[f"up{l}.w"], params[f"up{l}.b"]
                    ph0 = w[:, :, 0] @ d + b[:, None]
                    ph1 = w[:, :, 1] @ d + b[:, None]
                    states[cache] = jnp.concatenate([ph0, ph1], axis=1)
                else:
                    states[cache] = d
            reader_delayed = (l - 1 >= 1 and (l - 1) in d_dec) or (l == 1 and s == 1)
            reads_here = part == "all" or (
                part == "pre" if reader_delayed else part == "rest"
            )
            if reads_here:
                if cfg.extrap_of(l) == "tconv":
                    d = states[cache][:, 0:1] if fresh else states[cache][:, 1:2]
                else:
                    d = states[cache]
            else:
                d = None
        # FP boundary handoff (pre pass writes; rest pass reads above)
        if (
            part == "pre"
            and s is not None
            and s not in cfg.scc
            and l == s
            and phase % cfg.r_out(l) == 0
            and s != 1
            and d is not None
        ):
            states["fp.handoff"] = d

    if part == "pre":
        if s == 1:
            # whole network delayed: the head output itself is the handoff
            assert d is not None
            states["fp.handoff"] = _conv_step(
                d, params["head.w"], params["head.b"], use_pallas
            )
        return None, states

    if s == 1 and part == "rest":
        out_frame = states["fp.handoff"]
    else:
        assert d is not None
        out_frame = _conv_step(d, params["head.w"], params["head.b"], use_pallas)
    return out_frame, states


def run_streaming(
    cfg: UNetConfig,
    params: Params,
    x: jnp.ndarray,
    use_pallas: bool = False,
    split_fp: bool = False,
) -> jnp.ndarray:
    """Drive the streaming model over a whole sequence (python loop).

    With ``split_fp`` the FP pre/rest split is exercised instead of the
    monolithic step — outputs must be identical.
    """
    t = x.shape[1]
    states = init_states(cfg)
    outs = []
    for tt in range(t):
        phase = tt % cfg.period
        frame = x[:, tt : tt + 1]
        if split_fp and cfg.shift_pos is not None:
            _, states = streaming_step(
                cfg, params, phase, None, states, use_pallas, part="pre"
            )
            out, states = streaming_step(
                cfg, params, phase, frame, states, use_pallas, part="rest"
            )
        else:
            out, states = streaming_step(cfg, params, phase, frame, states, use_pallas)
        outs.append(out)
    return jnp.concatenate(outs, axis=1)


def phase_signature(cfg: UNetConfig, phase: int, part: str = "all") -> Tuple:
    """Canonical key of a phase's computation graph, for deduping identical
    step executables across phases (e.g. phases 1 and 3 of 2×S-CC)."""
    ticks = tuple(
        (
            phase % cfg.r_in(l) == 0,
            (phase % (2 * cfg.r_in(l)) == 0) if l in cfg.scc else None,
            phase % cfg.r_out(l) == 0,
        )
        for l in range(1, cfg.depth + 1)
    )
    return (part, ticks)
