"""Synthetic data substrates (DESIGN.md §5 substitutions).

The paper trains on the DNS-Challenge 2020 corpus (speech separation) and
the TAU Urban ASC 2020 Mobile set (scene classification); neither is
available in this offline environment.  These generators produce the
closest synthetic equivalents that exercise the same code paths:

* `speech`: a harmonic voiced source with a pitch-contour random walk,
  slowly varying formant-like resonances and on/off voicing envelope —
  nonstationary, broadband, speech-shaped.
* `noise`: colored noise with a random spectral tilt plus optional
  amplitude modulation (babble/street-like energy fluctuation).
* `scene`: K synthetic acoustic-scene classes, each defined by a fixed
  spectral envelope plus class-specific event statistics; labels change
  slowly relative to the frame rate — the regime the paper credits for
  SOI's zero quality loss on ASC.

The rust evaluation substrate (`rust/src/dsp/siggen.rs`) implements the
same family with the same parameters so both sides of the stack evaluate
the same distribution.
"""

from __future__ import annotations

import numpy as np

FS = 16_000  # Hz, the paper's sample rate


def speech(rng: np.random.Generator, n: int, fs: int = FS) -> np.ndarray:
    """Speech-like clean source, float32 in [-1, 1]."""
    t = np.arange(n) / fs
    # pitch contour: log-domain random walk within 80..300 Hz
    f0 = np.exp(
        np.clip(
            np.log(120.0)
            + np.cumsum(rng.standard_normal(n)) * 0.0006,
            np.log(80.0),
            np.log(300.0),
        )
    )
    phase = 2.0 * np.pi * np.cumsum(f0) / fs
    sig = np.zeros(n)
    # harmonic stack with 1/h roll-off, jittered amplitudes
    for h in range(1, 13):
        amp = (1.0 / h) * (0.5 + rng.random())
        sig += amp * np.sin(h * phase + rng.random() * 2 * np.pi)
    # two formant-like resonators (slowly wandering center frequencies)
    for fc0, bw in ((500.0, 120.0), (1500.0, 200.0)):
        fc = fc0 * (1.0 + 0.3 * np.sin(2 * np.pi * 0.7 * rng.random() * t))
        r = np.exp(-np.pi * bw / fs)
        # time-varying two-pole resonator applied sample-recursively would
        # be slow in numpy; a fixed-mid-frequency biquad is close enough
        from scipy.signal import lfilter

        w = 2 * np.pi * float(fc.mean()) / fs
        a1, a2 = -2 * r * np.cos(w), r * r
        y = lfilter([1.0 - r], [1.0, a1, a2], sig)
        sig = 0.5 * sig + 0.5 * y
    # voicing envelope: smoothed on/off gates (pauses between "words")
    gate = (rng.random(n // 1600 + 1) > 0.3).astype(float)
    env = np.repeat(gate, 1600)[:n]
    kern = np.hanning(801)
    kern /= kern.sum()
    env = np.convolve(env, kern, mode="same")
    sig *= env
    peak = np.abs(sig).max() + 1e-9
    return (sig / peak * 0.7).astype(np.float32)


def noise(rng: np.random.Generator, n: int, fs: int = FS) -> np.ndarray:
    """Colored noise with random spectral tilt and amplitude modulation."""
    white = rng.standard_normal(n)
    spec = np.fft.rfft(white)
    f = np.fft.rfftfreq(n, 1.0 / fs)
    tilt = rng.uniform(-1.2, 0.2)  # dB/octave-ish exponent
    shape = (np.maximum(f, 20.0) / 1000.0) ** tilt
    colored = np.fft.irfft(spec * shape, n)
    # slow amplitude modulation (street/babble energy fluctuation)
    mod = 1.0 + 0.5 * np.sin(
        2 * np.pi * rng.uniform(0.3, 2.0) * np.arange(n) / fs + rng.random() * 6.28
    )
    colored *= mod
    peak = np.abs(colored).max() + 1e-9
    return (colored / peak * 0.7).astype(np.float32)


def mix(clean: np.ndarray, nse: np.ndarray, snr_db: float) -> np.ndarray:
    """Scale noise to the requested SNR and add."""
    pc = np.mean(clean**2) + 1e-12
    pn = np.mean(nse**2) + 1e-12
    g = np.sqrt(pc / pn / (10.0 ** (snr_db / 10.0)))
    noisy = clean + g * nse
    return noisy.astype(np.float32)


def frames(x: np.ndarray, feat: int) -> np.ndarray:
    """Reshape a waveform into non-overlapping (feat, T) frame columns."""
    t = len(x) // feat
    return x[: t * feat].reshape(t, feat).T.astype(np.float32)


def denoise_batch(
    rng: np.random.Generator, batch: int, t_frames: int, feat: int, fs: int = FS
):
    """(noisy, clean) batches of shape (B, feat, T) for speech separation."""
    n = t_frames * feat
    xs, ys = [], []
    for _ in range(batch):
        c = speech(rng, n, fs)
        m = mix(c, noise(rng, n, fs), snr_db=float(rng.uniform(-5.0, 10.0)))
        xs.append(frames(m, feat))
        ys.append(frames(c, feat))
    return np.stack(xs), np.stack(ys)


# ---- synthetic acoustic scenes ---------------------------------------------

N_SCENES = 10  # TAU Urban ASC 2020 has 10 classes


def scene(rng: np.random.Generator, label: int, n: int, fs: int = FS) -> np.ndarray:
    """One synthetic acoustic scene of class `label` (0..N_SCENES-1).

    Class identity = a fixed spectral envelope (band emphasis) + an event
    train whose rate/length is class-specific.  Within-class variation
    comes from the noise seed and event placement.
    """
    assert 0 <= label < N_SCENES
    base = noise(rng, n, fs)
    # class-specific band emphasis
    spec = np.fft.rfft(base)
    f = np.fft.rfftfreq(n, 1.0 / fs)
    centers = np.linspace(200.0, 6000.0, N_SCENES)
    fc = centers[label]
    shape = 1.0 + 2.5 * np.exp(-(((f - fc) / (0.35 * fc + 200.0)) ** 2))
    sig = np.fft.irfft(spec * shape, n)
    # class-specific impulsive events (rate grows with label index)
    n_events = 1 + int(label * 1.5)
    for _ in range(n_events):
        pos = rng.integers(0, max(n - 400, 1))
        length = int(rng.integers(100, 400))
        burst = rng.standard_normal(length) * np.hanning(length)
        tone = np.sin(2 * np.pi * (fc * 1.5) * np.arange(length) / fs)
        sig[pos : pos + length] += 1.5 * burst * tone[: len(burst)]
    peak = np.abs(sig).max() + 1e-9
    return (sig / peak * 0.7).astype(np.float32)


def scene_batch(
    rng: np.random.Generator, batch: int, t_frames: int, feat: int, fs: int = FS
):
    """(frames, labels): (B, feat, T) scenes and (B,) int labels."""
    n = t_frames * feat
    xs, ys = [], []
    for _ in range(batch):
        lab = int(rng.integers(0, N_SCENES))
        xs.append(frames(scene(rng, lab, n, fs), feat))
        ys.append(lab)
    return np.stack(xs), np.asarray(ys, np.int32)


# ---- metrics ----------------------------------------------------------------


def si_snr(est: np.ndarray, target: np.ndarray, eps: float = 1e-8) -> float:
    """Scale-invariant SNR in dB over flattened signals."""
    est = est.reshape(-1) - est.mean()
    target = target.reshape(-1) - target.mean()
    s = np.dot(est, target) * target / (np.dot(target, target) + eps)
    e = est - s
    return float(10.0 * np.log10((np.dot(s, s) + eps) / (np.dot(e, e) + eps)))


def si_snr_improvement(noisy, est, clean) -> float:
    return si_snr(est, clean) - si_snr(noisy, clean)
