"""L2 correctness: the SOI streaming inference pattern.

The central theorem of STMC/SOI — and of this repo — is that single-frame
streaming inference with cached partial states reproduces the offline
(full-sequence) network *exactly*:

  * pure STMC: streaming == offline causal U-Net (paper eq. 3),
  * SOI PP:    streaming == offline strided-cloned network (eq. 4–6),
  * SOI FP:    streaming == offline shifted network (eq. 7), and the
               pre/rest split == the monolithic step.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

FEAT = 8
CH = (8, 10, 12, 14, 16, 18, 20)
BASE = dict(feat=FEAT, channels=CH)


def _x(t, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((FEAT, t)), jnp.float32)


def _assert_equiv(cfg, t=16, split=False, seed=1):
    params = M.init_params(cfg, seed=seed)
    x = _x(t, seed)
    off = M.offline_forward(cfg, params, x)
    st = M.run_streaming(cfg, params, x, split_fp=split)
    np.testing.assert_allclose(st, off, rtol=1e-4, atol=1e-5)


# ---- STMC baseline --------------------------------------------------------


def test_stmc_streaming_equals_offline():
    _assert_equiv(M.UNetConfig(**BASE))


def test_stmc_kernel4():
    _assert_equiv(M.UNetConfig(feat=FEAT, channels=CH[:5], kernel=4), t=12)


def test_shallow_depth3():
    _assert_equiv(M.UNetConfig(feat=FEAT, channels=(8, 12, 16), scc=(2,)), t=12)


# ---- SOI PP ---------------------------------------------------------------


@pytest.mark.parametrize("p", [1, 2, 3, 4, 5, 6, 7])
def test_pp_single_scc(p):
    _assert_equiv(M.UNetConfig(**BASE, scc=(p,)))


@pytest.mark.parametrize("pq", [(1, 3), (1, 6), (2, 5), (3, 6), (5, 7), (6, 7)])
def test_pp_double_scc(pq):
    _assert_equiv(M.UNetConfig(**BASE, scc=pq), t=16)


@pytest.mark.parametrize("p", [1, 4, 7])
def test_pp_tconv_extrap(p):
    _assert_equiv(M.UNetConfig(**BASE, scc=(p,), extrap="tconv"))


def test_pp_hybrid_extrap():
    _assert_equiv(M.UNetConfig(**BASE, scc=(2, 6), extrap=("duplicate", "tconv")))


# ---- SOI FP ---------------------------------------------------------------


@pytest.mark.parametrize("p", [1, 2, 5, 7])
def test_fp_sscc(p):
    """SS-CC p: stride + shift at the same position."""
    _assert_equiv(M.UNetConfig(**BASE, scc=(p,), shift_pos=p))


@pytest.mark.parametrize("ps", [(1, 3), (2, 5), (4, 6), (6, 7)])
def test_fp_hybrid(ps):
    """S-CC p with the shift at a deeper layer s (Table 2 'S-CC p s')."""
    p, s = ps
    _assert_equiv(M.UNetConfig(**BASE, scc=(p,), shift_pos=s))


@pytest.mark.parametrize("n", [1, 2, 3, 4])
def test_predictive_n(n):
    """'Predictive N' baseline: whole-input delay of N frames (App. B)."""
    _assert_equiv(M.UNetConfig(**BASE, shift_pos=1, shift=n), split=True)


def test_strided_predictive():
    _assert_equiv(M.UNetConfig(**BASE, scc=(4,), shift_pos=1, shift=2), split=True)


# ---- FP pre/rest split ----------------------------------------------------


@pytest.mark.parametrize(
    "cfg",
    [
        M.UNetConfig(**BASE, scc=(2,), shift_pos=2),
        M.UNetConfig(**BASE, scc=(5,), shift_pos=5),
        M.UNetConfig(**BASE, scc=(7,), shift_pos=7),
        M.UNetConfig(**BASE, scc=(2,), shift_pos=5),
        M.UNetConfig(**BASE, scc=(1,), shift_pos=3),
        M.UNetConfig(**BASE, shift_pos=1, shift=1),
        M.UNetConfig(**BASE, scc=(5,), shift_pos=5, extrap="tconv"),
    ],
    ids=["sscc2", "sscc5", "sscc7", "hybrid2-5", "hybrid1-3", "pred1", "sscc5-tconv"],
)
def test_fp_split_equals_monolithic(cfg):
    params = M.init_params(cfg, seed=2)
    x = _x(16, 4)
    mono = M.run_streaming(cfg, params, x, split_fp=False)
    split = M.run_streaming(cfg, params, x, split_fp=True)
    np.testing.assert_allclose(split, mono, rtol=1e-5, atol=1e-6)


def test_fp_pre_ignores_current_frame():
    """The precompute pass must not read the incoming frame at all."""
    cfg = M.UNetConfig(**BASE, scc=(2,), shift_pos=2)
    params = M.init_params(cfg, seed=2)
    states = M.init_states(cfg)
    # warm up with a few frames
    x = _x(8, 9)
    for t in range(8):
        _, states = M.streaming_step(cfg, params, t % cfg.period, x[:, t : t + 1], states)
    _, s_a = M.streaming_step(cfg, params, 0, None, states, part="pre")
    _, s_b = M.streaming_step(cfg, params, 0, None, states, part="pre")
    for k in s_a:
        np.testing.assert_array_equal(s_a[k], s_b[k])


# ---- streaming with the Pallas kernels ------------------------------------


def test_streaming_with_pallas_kernels():
    cfg = M.UNetConfig(feat=FEAT, channels=CH[:4], scc=(2,))
    params = M.init_params(cfg, seed=5)
    x = _x(8, 5)
    a = M.run_streaming(cfg, params, x, use_pallas=False)
    b = M.run_streaming(cfg, params, x, use_pallas=True)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_offline_with_pallas_kernels():
    cfg = M.UNetConfig(feat=FEAT, channels=CH[:4], scc=(2,))
    params = M.init_params(cfg, seed=5)
    x = _x(16, 6)
    a = M.offline_forward(cfg, params, x, use_pallas=False)
    b = M.offline_forward(cfg, params, x, use_pallas=True)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


# ---- structural properties -------------------------------------------------


def test_state_specs_match_init_states():
    cfg = M.UNetConfig(**BASE, scc=(2, 5), shift_pos=5, shift=2)
    specs = M.state_specs(cfg)
    states = M.init_states(cfg)
    assert [s.name for s in specs] == list(states.keys())
    for s in specs:
        assert states[s.name].shape == s.shape


def test_period():
    assert M.UNetConfig(**BASE).period == 1
    assert M.UNetConfig(**BASE, scc=(3,)).period == 2
    assert M.UNetConfig(**BASE, scc=(3, 5)).period == 4


def test_phase_signature_dedupes_shallow_phases():
    """Phases 1 and 3 of a 2×S-CC variant run the same graph."""
    cfg = M.UNetConfig(**BASE, scc=(2, 5))
    assert M.phase_signature(cfg, 1) == M.phase_signature(cfg, 3)
    assert M.phase_signature(cfg, 0) != M.phase_signature(cfg, 2)


def test_param_count_soi_adds_skip_params():
    """SOI variants keep the U-Net parameter inventory (skips are native);
    tconv extrapolation adds the learned upsample kernel."""
    n_stmc = M.param_count(M.UNetConfig(**BASE))
    n_dup = M.param_count(M.UNetConfig(**BASE, scc=(3,)))
    n_tconv = M.param_count(M.UNetConfig(**BASE, scc=(3,), extrap="tconv"))
    assert n_dup == n_stmc
    assert n_tconv > n_dup


def test_interp_variants_offline_only():
    cfg = M.UNetConfig(**BASE, scc=(3,), interp="linear")
    params = M.init_params(cfg)
    out = M.offline_forward(cfg, params, _x(16))
    assert out.shape == (FEAT, 16)
    with pytest.raises(NotImplementedError):
        M.streaming_step(cfg, params, 0, _x(2)[:, :1], M.init_states(cfg))


def test_causality_of_streaming():
    """Changing future frames cannot change past outputs (online property)."""
    cfg = M.UNetConfig(**BASE, scc=(2,), shift_pos=2)
    params = M.init_params(cfg, seed=8)
    x = _x(12, 3)
    y1 = M.run_streaming(cfg, params, x)
    x2 = x.at[:, 8:].set(5.0)
    y2 = M.run_streaming(cfg, params, x2)
    np.testing.assert_allclose(y1[:, :8], y2[:, :8], rtol=1e-6, atol=1e-7)
