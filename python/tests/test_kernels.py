"""L1 correctness: Pallas kernels vs the pure-jnp oracles in ref.py.

Hypothesis sweeps shapes/dtypes; every property here is the contract the
AOT artifacts (and therefore the rust hot path) rely on.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import conv_full, conv_step, dense, ref, vmem_footprint_bytes

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


@given(
    c_in=st.integers(1, 9),
    c_out=st.integers(1, 9),
    k=st.integers(1, 5),
    t=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv_full_matches_ref(c_in, c_out, k, t, seed):
    rng = np.random.default_rng(seed)
    x, w, b = _rand(rng, c_in, t), _rand(rng, c_out, c_in, k), _rand(rng, c_out)
    got = conv_full(x, w, b, tile_t=8)
    want = ref.causal_conv1d(x, w, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@given(
    c_in=st.integers(1, 8),
    c_out=st.integers(1, 8),
    k=st.integers(1, 4),
    bsz=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv_step_matches_ref(c_in, c_out, k, bsz, seed):
    rng = np.random.default_rng(seed)
    win = _rand(rng, bsz, c_in, k)
    w, b = _rand(rng, c_out, c_in, k), _rand(rng, c_out)
    got = conv_step(win, w, b)
    for i in range(bsz):
        want, _ = ref.conv_step(win[i, :, -1:], win[i, :, :-1], w, b)
        np.testing.assert_allclose(got[i], want[:, 0], rtol=1e-5, atol=1e-5)


@given(t=st.integers(1, 50), seed=st.integers(0, 2**31 - 1))
def test_streaming_conv_state_carry(t, seed):
    """Feeding frames one at a time through conv_step == offline conv_full."""
    rng = np.random.default_rng(seed)
    c_in, c_out, k = 4, 6, 3
    x = _rand(rng, c_in, t)
    w, b = _rand(rng, c_out, c_in, k), _rand(rng, c_out)
    state = jnp.zeros((c_in, k - 1))
    outs = []
    for tt in range(t):
        win = jnp.concatenate([state, x[:, tt : tt + 1]], axis=1)
        outs.append(conv_step(win[None], w, b)[0])
        state = win[:, 1:]
    got = jnp.stack(outs, axis=1)
    want = ref.causal_conv1d(x, w, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_conv_full_kernel_one():
    """K=1 conv == per-frame dense layer."""
    rng = np.random.default_rng(0)
    x, w, b = _rand(rng, 5, 12), _rand(rng, 3, 5, 1), _rand(rng, 3)
    got = conv_full(x, w, b, tile_t=4)
    want = w[:, :, 0] @ x + b[:, None]
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_conv_full_tile_independence():
    """Result must not depend on the time tile size."""
    rng = np.random.default_rng(7)
    x, w, b = _rand(rng, 6, 37), _rand(rng, 4, 6, 3), _rand(rng, 4)
    a = conv_full(x, w, b, tile_t=8)
    c = conv_full(x, w, b, tile_t=64)
    np.testing.assert_allclose(a, c, rtol=1e-6, atol=1e-6)


@given(
    n=st.integers(1, 16), m=st.integers(1, 16), bsz=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_matches_ref(n, m, bsz, seed):
    rng = np.random.default_rng(seed)
    x, w, b = _rand(rng, bsz, n), _rand(rng, m, n), _rand(rng, m)
    got = dense(x, w, b)
    for i in range(bsz):
        np.testing.assert_allclose(got[i], ref.dense(x[i], w, b), rtol=1e-5, atol=1e-5)


def test_causality():
    """Future inputs must not influence past outputs."""
    rng = np.random.default_rng(3)
    c_in, c_out, k, t = 3, 4, 3, 20
    x = _rand(rng, c_in, t)
    w, b = _rand(rng, c_out, c_in, k), _rand(rng, c_out)
    y0 = conv_full(x, w, b, tile_t=8)
    x2 = x.at[:, 10:].set(99.0)
    y2 = conv_full(x2, w, b, tile_t=8)
    np.testing.assert_allclose(y0[:, :10], y2[:, :10], rtol=1e-6, atol=1e-6)


# ---- extrapolation / interpolation oracles -------------------------------


def test_duplicate_upsample_pp_alignment():
    y = jnp.arange(1.0, 5.0)[None, :]  # 1 2 3 4
    up = ref.duplicate_upsample(y, 8, shift=0)
    np.testing.assert_allclose(up[0], [1, 1, 2, 2, 3, 3, 4, 4])


def test_duplicate_upsample_fp_alignment():
    y = jnp.arange(1.0, 5.0)[None, :]
    up = ref.duplicate_upsample(y, 8, shift=1)
    # eq. 7: value computed at 2s is used at 2s+1 and 2s+2; t=0 has nothing
    np.testing.assert_allclose(up[0], [0, 1, 1, 2, 2, 3, 3, 4])


def test_interp_linear_midpoints():
    y = jnp.asarray([[0.0, 2.0, 4.0]])
    up = ref.interp_upsample(y, 6, "linear")
    np.testing.assert_allclose(up[0], [0, 1, 2, 3, 4, 4])


def test_interp_nearest_rounds_up():
    y = jnp.asarray([[0.0, 2.0, 4.0]])
    up = ref.interp_upsample(y, 6, "nearest")
    np.testing.assert_allclose(up[0], [0, 2, 2, 4, 4, 4])


def test_interp_cubic_passes_through_knots():
    rng = np.random.default_rng(11)
    y = _rand(rng, 2, 6)
    up = ref.interp_upsample(y, 12, "cubic")
    np.testing.assert_allclose(up[:, 0::2], y, rtol=1e-5, atol=1e-5)


def test_interp_unknown_kind_raises():
    with pytest.raises(ValueError):
        ref.interp_upsample(jnp.zeros((1, 4)), 8, "quintic")


def test_tconv_upsample_shapes_and_shift():
    rng = np.random.default_rng(5)
    y = _rand(rng, 3, 4)
    w, b = _rand(rng, 2, 3, 2), _rand(rng, 2)
    up0 = ref.transposed_conv_upsample(y, w, b, 8, shift=0)
    up1 = ref.transposed_conv_upsample(y, w, b, 8, shift=1)
    assert up0.shape == (2, 8)
    np.testing.assert_allclose(up1[:, 1:], up0[:, :-1], rtol=1e-6)
    np.testing.assert_allclose(up1[:, 0], 0.0)


def test_vmem_footprint_within_budget():
    """Every layer shape used in this repo fits VMEM comfortably (§Perf)."""
    worst = vmem_footprint_bytes(c_in=160, c_out=96, k=3, tile_t=128)
    assert worst["total"] < 2 * 1024 * 1024  # far under the ~16 MB budget


# ---- int8 reference kernels (the rust quant subsystem's mirror) -----------


def test_int8_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(3)
    w = _rand(rng, 5, 4, 3)
    q, s = ref.int8_quantize_weights(w)
    assert q.dtype == np.int8 and np.abs(q).max() <= ref.Q_W
    deq = q.reshape(-1, 3).astype(np.float32) * s[:, None]
    err = np.abs(deq.reshape(w.shape) - np.asarray(w))
    # per-group scales bound elementwise error by half an LSB
    assert (err <= 0.5 * s.max() + 1e-7).all()


def test_int8_conv_matches_fakequant_f32():
    rng = np.random.default_rng(4)
    c_out, c_in, k = 3, 4, 3
    w = _rand(rng, c_out, c_in, k)
    b = _rand(rng, c_out)
    q, s = ref.int8_quantize_weights(w)
    s_x = np.float32(1e-3)
    win_q = rng.integers(-32000, 32000, size=c_in * k)
    got = ref.int8_conv_win(q, s, s_x, b, win_q)
    deq_w = q.reshape(-1, k).astype(np.float32) * s[:, None]
    deq_w = deq_w.reshape(c_out, c_in * k)
    deq_x = win_q.astype(np.float32) * s_x
    want = deq_w @ deq_x + np.asarray(b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_elu_lut_identity_positive_and_close_negative():
    scale = 2e-4
    table = ref.elu_lut_table(scale)
    q = np.array([0, 1, 500, 32767, -1, -33, -1000, -32767])
    out = ref.elu_lut_apply(table, q)
    np.testing.assert_array_equal(out[q >= 0], q[q >= 0])
    want = np.expm1(q[q < 0] * scale) / scale
    assert np.abs(out[q < 0] - want).max() <= 2.0


def test_s16_quantize_rounds_half_away_and_saturates():
    assert ref.s16_quantize(0.26, 0.1) == 3
    assert ref.s16_quantize(-0.26, 0.1) == -3
    assert ref.s16_quantize(1e9, 0.1) == ref.Q_ACT
    assert ref.s16_quantize(-1e9, 0.1) == -ref.Q_ACT
